//! A compiled artifact: HLO text -> PJRT executable + typed host I/O.
//!
//! The real implementation needs the `xla` crate and lives behind the
//! `pjrt` feature; the default offline build compiles a stub that carries
//! the spec (so every signature downstream typechecks) and errors on
//! execution. `Runtime::load` refuses to construct the stub, so the error
//! surfaces at load time with a clear message.

use super::artifact::ArtifactSpec;
use crate::data::{Array, Batch};
use crate::util::error::{bail, Context, Result};

/// A compiled, ready-to-run computation.
pub struct Executable {
    pub spec: ArtifactSpec,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

/// Stage one host array on the device.
///
/// NOTE: this deliberately uses `buffer_from_host_buffer` + `execute_b`
/// rather than `execute::<Literal>`: the literal path in the bundled
/// xla_extension leaks the converted input buffers (~input-size bytes per
/// call, measured in examples/_leaktest.rs history — see EXPERIMENTS.md
/// §Perf), while the host-buffer path is leak-free and skips one copy.
#[cfg(feature = "pjrt")]
fn buffer_from_array(client: &xla::PjRtClient, a: &Array) -> Result<xla::PjRtBuffer> {
    let b = match a {
        Array::F32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
        Array::I32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
    };
    Ok(b)
}

#[cfg(feature = "pjrt")]
fn array_from_literal(lit: &xla::Literal, spec: &crate::runtime::IoSpec) -> Result<Array> {
    let shape = spec.shape.clone();
    match spec.dtype.as_str() {
        "f32" => Ok(Array::F32(lit.to_vec::<f32>()?, shape)),
        "i32" => Ok(Array::I32(lit.to_vec::<i32>()?, shape)),
        other => bail!("unsupported output dtype {other}"),
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Access the underlying PJRT executable (benches / probes).
    pub fn raw(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }

    /// Compile `spec`'s HLO text on the given PJRT client.
    pub fn compile(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(&spec.hlo_path)
            .with_context(|| format!("parsing HLO text {:?}", spec.hlo_path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(Executable {
            spec: spec.clone(),
            exe,
        })
    }

    /// Execute with an optional leading flat-parameter vector plus the
    /// batch arrays (manifest order). Returns the output arrays.
    pub fn run(&self, params: Option<&[f32]>, batch: &Batch) -> Result<Vec<Array>> {
        let client = self.exe.client();
        let mut buffers: Vec<xla::PjRtBuffer> = Vec::with_capacity(batch.len() + 1);
        if self.spec.param_dim > 0 {
            let p = params.context("artifact expects a parameter vector")?;
            if p.len() != self.spec.param_dim {
                bail!(
                    "{}: params len {} != param_dim {}",
                    self.spec.name,
                    p.len(),
                    self.spec.param_dim
                );
            }
            buffers.push(client.buffer_from_host_buffer(p, &[p.len()], None)?);
        }
        if batch.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} batch arrays, expected {}",
                self.spec.name,
                batch.len(),
                self.spec.inputs.len()
            );
        }
        for (a, spec) in batch.iter().zip(&self.spec.inputs) {
            if a.numel() != spec.numel() || a.dtype_str() != spec.dtype {
                bail!(
                    "{}: input {} mismatch (got {:?}/{}, want {:?}/{})",
                    self.spec.name,
                    spec.name,
                    a.shape(),
                    a.dtype_str(),
                    spec.shape,
                    spec.dtype
                );
            }
            buffers.push(buffer_from_array(client, a)?);
        }
        let result = self.exe.execute_b(&buffers)?;
        let tuple = result[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: always a tuple at the root.
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| array_from_literal(lit, spec))
            .collect()
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Stub: execution requires the `pjrt` feature.
    pub fn run(&self, _params: Option<&[f32]>, _batch: &Batch) -> Result<Vec<Array>> {
        bail!(
            "{}: built without the `pjrt` feature; cannot execute",
            self.spec.name
        )
    }
}

impl Executable {
    /// Convenience for train artifacts: returns (loss, grads).
    pub fn run_train(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        let outs = self.run(Some(params), batch)?;
        let loss = outs[0]
            .as_f32()
            .and_then(|v| v.first().copied())
            .context("train output 0 must be the f32 loss")?;
        let grads = match outs.into_iter().nth(1) {
            Some(Array::F32(g, _)) => g,
            _ => bail!("train output 1 must be the f32 gradient vector"),
        };
        Ok((loss, grads))
    }
}
