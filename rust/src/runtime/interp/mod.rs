//! Native interpreter backend: executes train/eval artifacts as plain
//! Rust, no XLA toolchain required.
//!
//! An artifact is interpretable when its manifest record carries a
//! [`ProgramSpec`] — emitted by `python/compile/aot.py` next to the HLO
//! text, or supplied by the hand-written fallback specs in [`builtin`]
//! when no `artifacts/` directory exists at all. The interpreter covers
//! the small paper artifacts (linreg, MLP classifier); the larger models
//! still need the `pjrt` feature and a toolchain image.
//!
//! Correctness contract (validated by `tests/runtime_golden.rs` and
//! `tests/interp_grad_check.rs`):
//! * f32 storage, f64 accumulation in every kernel ([`ops`]);
//! * loss / grad checksums match the straight-line f64 reference
//!   ([`reference`]) that mints the builtin goldens;
//! * every backward op passes a finite-difference check.

pub mod builtin;
pub mod ops;
pub mod program;
pub mod reference;

pub use program::{Act, Dense, Loss, ProgramSpec};

use crate::data::{Array, Batch};
use crate::runtime::artifact::ArtifactSpec;
use crate::util::error::{bail, Context, Result};
use crate::util::prng::Rng;

/// A prepared interpreter executable for one artifact.
#[derive(Debug, Clone)]
pub struct InterpExec {
    prog: ProgramSpec,
}

impl InterpExec {
    /// Build from an artifact spec; fails with a clear message when the
    /// artifact has no program description.
    pub fn new(spec: &ArtifactSpec) -> Result<InterpExec> {
        let prog = spec.program.clone().with_context(|| {
            format!(
                "artifact {:?} has no interpreter program: only the linreg/mlp \
                 artifacts are interpretable (builtin specs or a manifest with \
                 \"program\" records). For the other artifacts build with \
                 `--features pjrt` on a toolchain image that vendors the real \
                 xla crate",
                spec.name
            )
        })?;
        prog.validate()?;
        if spec.param_dim != prog.param_dim() {
            bail!(
                "{}: program param dim {} != manifest param_dim {}",
                spec.name,
                prog.param_dim(),
                spec.param_dim
            );
        }
        let in_numel = spec
            .inputs
            .first()
            .map(|io| io.numel())
            .context("artifact has no batch inputs")?;
        if in_numel % prog.in_dim() != 0 {
            bail!(
                "{}: first input numel {} not divisible by program in_dim {}",
                spec.name,
                in_numel,
                prog.in_dim()
            );
        }
        if matches!(prog.loss, Loss::SoftmaxXent { .. } | Loss::SigmoidBce)
            && spec.inputs.len() < 2
        {
            bail!("{}: labelled loss needs an i32 label input", spec.name);
        }
        Ok(InterpExec { prog })
    }

    pub fn program(&self) -> &ProgramSpec {
        &self.prog
    }

    fn batch_views<'a>(&self, batch: &'a Batch) -> Result<(&'a [f32], usize, Option<&'a [i32]>)> {
        let x = batch[0].as_f32().context("input 0 must be f32 features")?;
        let m = x.len() / self.prog.in_dim();
        let y = match self.prog.loss {
            Loss::SoftmaxXent { .. } | Loss::SigmoidBce => {
                Some(batch[1].as_i32().context("input 1 must be i32 labels")?)
            }
            Loss::MeanSquare => None,
        };
        Ok((x, m, y))
    }

    /// Forward pass: returns each layer's post-activation output.
    fn forward(&self, params: &[f32], x: &[f32], m: usize) -> Vec<Vec<f32>> {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.prog.layers.len());
        for (li, l) in self.prog.layers.iter().enumerate() {
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1] };
            let mut h = vec![0.0f32; m * l.out_dim];
            let w = &params[l.w_off..l.w_off + l.w_len()];
            ops::matmul(input, m, l.in_dim, w, l.out_dim, &mut h);
            if let Some(b_off) = l.b_off {
                ops::bias_add(&mut h, m, l.out_dim, &params[b_off..b_off + l.out_dim]);
            }
            match l.act {
                Act::Linear => {}
                Act::Relu => ops::relu(&mut h),
                Act::Sigmoid => ops::sigmoid(&mut h),
            }
            acts.push(h);
        }
        acts
    }

    fn loss_grad(&self, out: &[f32], y: Option<&[i32]>, m: usize, dh: &mut [f32]) -> f64 {
        match self.prog.loss {
            Loss::MeanSquare => ops::mean_square_loss(out, m, self.prog.out_dim(), dh),
            Loss::SoftmaxXent { classes } => {
                ops::softmax_xent_loss(out, y.expect("labels validated in new()"), m, classes, dh)
            }
            Loss::SigmoidBce => {
                ops::sigmoid_bce_loss(out, y.expect("labels validated in new()"), m, dh)
            }
        }
    }

    /// Train step with streaming gradient segments.
    ///
    /// The backward pass walks layers last-to-first — the real DDP
    /// arrival order — and invokes `on_segment(grads_so_far, offset, len)`
    /// the moment each parameter block's gradient is final, with the block
    /// already written into `grad_out`. Returns the batch loss.
    pub fn run_train_stream(
        &self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        on_segment: &mut dyn FnMut(&[f32], usize, usize),
    ) -> Result<f32> {
        let (x, m, y) = self.batch_views(batch)?;
        if grad_out.len() != self.prog.param_dim() {
            bail!(
                "grad_out len {} != param dim {}",
                grad_out.len(),
                self.prog.param_dim()
            );
        }
        let acts = self.forward(params, x, m);
        let out = acts.last().expect("validated non-empty program");
        let mut dh = vec![0.0f32; out.len()];
        let loss = self.loss_grad(out, y, m, &mut dh);
        for li in (0..self.prog.layers.len()).rev() {
            let l = &self.prog.layers[li];
            let (k, n) = (l.in_dim, l.out_dim);
            let input: &[f32] = if li == 0 { x } else { &acts[li - 1] };
            match l.act {
                Act::Linear => {}
                Act::Relu => ops::relu_backward(&acts[li], &mut dh),
                Act::Sigmoid => ops::sigmoid_backward(&acts[li], &mut dh),
            }
            if let Some(b_off) = l.b_off {
                ops::bias_db(&dh, m, n, &mut grad_out[b_off..b_off + n]);
                on_segment(grad_out, b_off, n);
            }
            ops::matmul_dw(input, &dh, m, k, n, &mut grad_out[l.w_off..l.w_off + l.w_len()]);
            on_segment(grad_out, l.w_off, l.w_len());
            if li > 0 {
                let w = &params[l.w_off..l.w_off + l.w_len()];
                let mut dx = vec![0.0f32; m * k];
                ops::matmul_dx(&dh, w, m, k, n, &mut dx);
                dh = dx;
            }
        }
        Ok(loss as f32)
    }

    /// Execute the artifact, producing outputs in manifest order.
    pub fn run(&self, spec: &ArtifactSpec, params: &[f32], batch: &Batch) -> Result<Vec<Array>> {
        let (x, m, y) = self.batch_views(batch)?;
        if spec.kind == "train" {
            let mut grads = vec![0.0f32; self.prog.param_dim()];
            let loss = self.run_train_stream(params, batch, &mut grads, &mut |_, _, _| {})?;
            return Ok(vec![
                Array::F32(vec![loss], vec![]),
                Array::F32(grads, vec![self.prog.param_dim()]),
            ]);
        }
        // Eval graph: loss (+ per-example `correct` for classifiers).
        let acts = self.forward(params, x, m);
        let out = acts.last().expect("validated non-empty program");
        let mut scratch = vec![0.0f32; out.len()];
        let loss = self.loss_grad(out, y, m, &mut scratch) as f32;
        let mut outs = vec![Array::F32(vec![loss], vec![])];
        if spec.outputs.len() > 1 {
            match (&self.prog.loss, y) {
                (Loss::SoftmaxXent { classes }, Some(y)) => {
                    let mut correct = vec![0.0f32; m];
                    ops::argmax_correct(out, y, m, *classes, &mut correct);
                    outs.push(Array::F32(correct, vec![m]));
                }
                (Loss::SigmoidBce, Some(y)) => {
                    // Predicted class = σ(z) > 0.5 ⇔ z > 0.
                    let correct: Vec<f32> = out
                        .iter()
                        .zip(y)
                        .map(|(&z, &t)| ((z > 0.0) as i32 == t) as i32 as f32)
                        .collect();
                    outs.push(Array::F32(correct, vec![m]));
                }
                _ => bail!(
                    "{}: eval outputs beyond loss need a classifier program",
                    spec.name
                ),
            }
        }
        Ok(outs)
    }
}

/// Deterministic parameter init for artifacts without init blobs: per
/// layer, weights ~ N(0, init_std) from a seed-keyed stream, biases zero.
/// Independent of the artifact name so linreg_b16/b64/b128 share inits,
/// matching the aot.py behaviour (init depends only on model + seed).
pub fn init_params(prog: &ProgramSpec, seed: u64) -> Vec<f32> {
    let mut p = vec![0.0f32; prog.param_dim()];
    for (li, l) in prog.layers.iter().enumerate() {
        let mut rng = Rng::new(seed.wrapping_add(0x5EED_1A17)).fork(li as u64);
        rng.fill_normal_f32(&mut p[l.w_off..l.w_off + l.w_len()], l.init_std);
    }
    p
}

/// The deterministic golden batch both `aot.py` and the Rust tests
/// regenerate bit-identically: f32 arrays filled with 0.5, int arrays
/// `index % cardinality` (cardinality from the artifact meta).
pub fn golden_batch(spec: &ArtifactSpec) -> Batch {
    spec.inputs
        .iter()
        .map(|io| {
            let n = io.numel();
            if io.dtype == "f32" {
                Array::F32(vec![0.5; n], io.shape.clone())
            } else {
                let card = match io.name.as_str() {
                    "y" => spec.meta.get("classes").as_usize().unwrap_or(2),
                    "cat" | "tokens" => spec.meta.get("vocab").as_usize().unwrap_or(2),
                    _ => 2,
                } as i64;
                Array::I32(
                    (0..n as i64).map(|i| (i % card) as i32).collect(),
                    io.shape.clone(),
                )
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_linreg_interprets_and_matches_reference() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("linreg_b16").unwrap();
        let exec = InterpExec::new(spec).unwrap();
        let params = spec.load_init(0).unwrap();
        let batch = golden_batch(spec);
        let outs = exec.run(spec, &params, &batch).unwrap();
        assert_eq!(outs.len(), 2);
        let golden = spec.golden.as_ref().unwrap();
        let loss = outs[0].as_f32().unwrap()[0] as f64;
        // Tolerance: interpreter stores f32 at layer boundaries but
        // accumulates in f64, so it sits within ~1e-6 relative of the
        // all-f64 reference; 1e-4 leaves margin.
        assert!(
            (loss - golden.loss).abs() / golden.loss.abs().max(1e-9) < 1e-4,
            "loss {loss} vs golden {}",
            golden.loss
        );
    }

    #[test]
    fn streamed_segments_cover_every_parameter_once() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("mlp_cls_b32").unwrap();
        let exec = InterpExec::new(spec).unwrap();
        let params = spec.load_init(0).unwrap();
        let batch = golden_batch(spec);
        let d = spec.param_dim;
        let mut grads = vec![0.0f32; d];
        let mut covered = vec![false; d];
        let mut offsets = Vec::new();
        let r = exec.run_train_stream(&params, &batch, &mut grads, &mut |_, off, len| {
            offsets.push(off);
            for c in &mut covered[off..off + len] {
                assert!(!*c, "segment overlap at {off}");
                *c = true;
            }
        });
        r.unwrap();
        assert!(covered.iter().all(|&c| c), "segments must tile the params");
        // Backward order: later layers' blocks stream first.
        assert!(offsets.first().unwrap() > offsets.last().unwrap());
    }

    #[test]
    fn init_params_deterministic_and_layerwise() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("mlp_cls_b32").unwrap();
        let prog = spec.program.as_ref().unwrap();
        let a = init_params(prog, 0);
        let b = init_params(prog, 0);
        let c = init_params(prog, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Biases zero, weights non-trivial.
        let l0 = &prog.layers[0];
        let b_off = l0.b_off.unwrap();
        assert!(a[b_off..b_off + l0.out_dim].iter().all(|&v| v == 0.0));
        assert!(a[l0.w_off..l0.w_off + 8].iter().any(|&v| v != 0.0));
    }
}
