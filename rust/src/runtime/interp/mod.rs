//! Native interpreter backend: executes train/eval artifacts as plain
//! Rust, no XLA toolchain required.
//!
//! An artifact is interpretable when its manifest record carries a
//! [`ProgramSpec`] — emitted by `python/compile/aot.py` next to the HLO
//! text, or supplied by the hand-written fallback specs in [`builtin`]
//! when no `artifacts/` directory exists at all. The interpreter covers
//! the paper's small artifacts (linreg, MLP classifier) plus the
//! dlrm-lite CTR model (embedding → layernormed dense chain →
//! sigmoid-BCE); the larger models still need the `pjrt` feature and a
//! toolchain image.
//!
//! Correctness contract (validated by `tests/runtime_golden.rs`,
//! `tests/interp_grad_check.rs` and `tests/interp_kernel_equiv.rs`):
//! * f32 storage, f64 accumulation in every kernel ([`ops`]);
//! * blocked / pool-sharded kernels bitwise-equal to the scalar oracle
//!   at every thread count (fixed per-element accumulation order);
//! * loss / grad checksums match the straight-line f64 reference
//!   ([`reference`]) that mints the builtin goldens;
//! * every backward op passes a finite-difference check.

pub mod builtin;
pub mod ops;
pub mod program;
pub mod reference;

pub use program::{Act, Dense, Embedding, LayerNorm, Loss, ProgramSpec};

use crate::data::{Array, Batch};
use crate::parallel::ParallelCtx;
use crate::runtime::artifact::ArtifactSpec;
use crate::util::error::{bail, Context, Result};
use crate::util::prng::Rng;

/// A prepared interpreter executable for one artifact.
#[derive(Debug, Clone)]
pub struct InterpExec {
    prog: ProgramSpec,
}

/// Label views: softmax wants i32 class ids, BCE wants f32 {0,1} clicks
/// (`data::ctr` emits f32; i32 label inputs are converted on the fly so
/// pre-existing BCE artifacts keep working).
enum Labels<'a> {
    None,
    I32(&'a [i32]),
    F32(&'a [f32]),
}

/// Decoded batch inputs for one run.
struct Views<'a> {
    /// Per-field embedding ids `(m, fields)` — embed programs only.
    cat: Option<&'a [i32]>,
    /// Dense features: input 0 for plain programs, input 1 (the dense
    /// tail) for embed programs.
    x: &'a [f32],
    m: usize,
    y: Labels<'a>,
}

/// Forward-pass caches the backward pass consumes.
struct Forward {
    /// Assembled first-layer input (embed programs only; empty otherwise).
    x0: Vec<f32>,
    /// Per-layer post-activation outputs.
    acts: Vec<Vec<f32>>,
    /// Per-layer LN normalized activations (empty when the layer has none).
    xhat: Vec<Vec<f32>>,
    /// Per-layer LN per-row inverse stddevs (empty when the layer has none).
    rstd: Vec<Vec<f64>>,
}

impl InterpExec {
    /// Build from an artifact spec; fails with a clear message when the
    /// artifact has no program description.
    pub fn new(spec: &ArtifactSpec) -> Result<InterpExec> {
        let prog = spec.program.clone().with_context(|| {
            format!(
                "artifact {:?} has no interpreter program: only the linreg/mlp/\
                 dlrm artifacts are interpretable (builtin specs or a manifest \
                 with \"program\" records). For the other artifacts build with \
                 `--features pjrt` on a toolchain image that vendors the real \
                 xla crate",
                spec.name
            )
        })?;
        prog.validate()?;
        if spec.param_dim != prog.param_dim() {
            bail!(
                "{}: program param dim {} != manifest param_dim {}",
                spec.name,
                prog.param_dim(),
                spec.param_dim
            );
        }
        let in_numel = spec
            .inputs
            .first()
            .map(|io| io.numel())
            .context("artifact has no batch inputs")?;
        if let Some(e) = &prog.embed {
            if in_numel % e.fields != 0 {
                bail!(
                    "{}: id input numel {} not divisible by embed fields {}",
                    spec.name,
                    in_numel,
                    e.fields
                );
            }
            if spec.inputs.len() < 2 {
                bail!("{}: embed program needs a dense-features input", spec.name);
            }
        } else if in_numel % prog.in_dim() != 0 {
            bail!(
                "{}: first input numel {} not divisible by program in_dim {}",
                spec.name,
                in_numel,
                prog.in_dim()
            );
        }
        let label_idx = if prog.embed.is_some() { 2 } else { 1 };
        if matches!(prog.loss, Loss::SoftmaxXent { .. } | Loss::SigmoidBce)
            && spec.inputs.len() <= label_idx
        {
            bail!("{}: labelled loss needs a label input", spec.name);
        }
        Ok(InterpExec { prog })
    }

    pub fn program(&self) -> &ProgramSpec {
        &self.prog
    }

    fn batch_views<'a>(&self, batch: &'a Batch) -> Result<Views<'a>> {
        let (cat, x, m, label_idx) = if let Some(e) = &self.prog.embed {
            let cat = batch[0].as_i32().context("input 0 must be i32 ids")?;
            let x = batch[1].as_f32().context("input 1 must be f32 dense features")?;
            (Some(cat), x, cat.len() / e.fields, 2usize)
        } else {
            let x = batch[0].as_f32().context("input 0 must be f32 features")?;
            (None, x, x.len() / self.prog.in_dim(), 1usize)
        };
        let y = match self.prog.loss {
            Loss::MeanSquare => Labels::None,
            Loss::SoftmaxXent { .. } => Labels::I32(
                batch[label_idx]
                    .as_i32()
                    .context("label input must be i32 class ids")?,
            ),
            Loss::SigmoidBce => match batch[label_idx].as_f32() {
                Some(v) => Labels::F32(v),
                None => Labels::I32(
                    batch[label_idx]
                        .as_i32()
                        .context("BCE label input must be f32 or i32")?,
                ),
            },
        };
        Ok(Views { cat, x, m, y })
    }

    /// Forward pass, sharding each matmul's batch rows over `ctx`'s pool
    /// (bitwise-identical to serial at any thread count — see `ops`).
    fn forward_ctx(&self, params: &[f32], views: &Views, ctx: &ParallelCtx) -> Forward {
        let m = views.m;
        let x0 = if let Some(e) = &self.prog.embed {
            let mut x0 = vec![0.0f32; m * e.x_dim()];
            let table = &params[e.t_off..e.t_off + e.t_len()];
            ops::embedding_forward(
                table,
                views.cat.expect("embed program validated ids input"),
                views.x,
                m,
                e.fields,
                e.vocab,
                e.dim,
                e.dense_dim,
                &mut x0,
            );
            x0
        } else {
            Vec::new()
        };
        let nl = self.prog.layers.len();
        let mut fw = Forward {
            x0,
            acts: Vec::with_capacity(nl),
            xhat: Vec::with_capacity(nl),
            rstd: Vec::with_capacity(nl),
        };
        for (li, l) in self.prog.layers.iter().enumerate() {
            let input: &[f32] = if li == 0 {
                if self.prog.embed.is_some() {
                    &fw.x0
                } else {
                    views.x
                }
            } else {
                &fw.acts[li - 1]
            };
            let mut h = vec![0.0f32; m * l.out_dim];
            let w = &params[l.w_off..l.w_off + l.w_len()];
            ops::matmul_ctx(ctx, input, m, l.in_dim, w, l.out_dim, &mut h);
            if let Some(b_off) = l.b_off {
                ops::bias_add(&mut h, m, l.out_dim, &params[b_off..b_off + l.out_dim]);
            }
            let (mut xhat, mut rstd) = (Vec::new(), Vec::new());
            if let Some(ln) = l.ln {
                xhat = vec![0.0f32; m * l.out_dim];
                rstd = vec![0.0f64; m];
                ops::layernorm_forward(
                    &mut h,
                    m,
                    l.out_dim,
                    &params[ln.g_off..ln.g_off + l.out_dim],
                    &params[ln.b_off..ln.b_off + l.out_dim],
                    &mut xhat,
                    &mut rstd,
                );
            }
            match l.act {
                Act::Linear => {}
                Act::Relu => ops::relu(&mut h),
                Act::Sigmoid => ops::sigmoid(&mut h),
            }
            fw.acts.push(h);
            fw.xhat.push(xhat);
            fw.rstd.push(rstd);
        }
        fw
    }

    fn loss_grad(&self, out: &[f32], y: &Labels, m: usize, dh: &mut [f32]) -> f64 {
        match self.prog.loss {
            Loss::MeanSquare => ops::mean_square_loss(out, m, self.prog.out_dim(), dh),
            Loss::SoftmaxXent { classes } => match y {
                Labels::I32(y) => ops::softmax_xent_loss(out, y, m, classes, dh),
                _ => unreachable!("labels validated in new()"),
            },
            Loss::SigmoidBce => match y {
                Labels::F32(y) => ops::sigmoid_bce_loss(out, y, m, dh),
                Labels::I32(y) => {
                    let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
                    ops::sigmoid_bce_loss(out, &yf, m, dh)
                }
                Labels::None => unreachable!("labels validated in new()"),
            },
        }
    }

    /// Train step with streaming gradient segments (serial compute).
    ///
    /// The backward pass walks layers last-to-first — the real DDP
    /// arrival order — and invokes `on_segment(grads_so_far, offset, len)`
    /// the moment each parameter block's gradient is final, with the block
    /// already written into `grad_out`. Returns the batch loss.
    pub fn run_train_stream(
        &self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        on_segment: &mut dyn FnMut(&[f32], usize, usize),
    ) -> Result<f32> {
        self.run_train_stream_ctx(params, batch, grad_out, &ParallelCtx::serial(), on_segment)
    }

    /// [`InterpExec::run_train_stream`] with the forward/backward matmuls
    /// sharded over `ctx`'s worker pool. The kernels write disjoint
    /// output bands in a fixed per-element order, so the gradients (and
    /// the segment stream) are bitwise-identical at every thread count.
    pub fn run_train_stream_ctx(
        &self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        ctx: &ParallelCtx,
        on_segment: &mut dyn FnMut(&[f32], usize, usize),
    ) -> Result<f32> {
        let views = self.batch_views(batch)?;
        let m = views.m;
        if grad_out.len() != self.prog.param_dim() {
            bail!(
                "grad_out len {} != param dim {}",
                grad_out.len(),
                self.prog.param_dim()
            );
        }
        let fw = self.forward_ctx(params, &views, ctx);
        let out = fw.acts.last().expect("validated non-empty program");
        let mut dh = vec![0.0f32; out.len()];
        let loss = self.loss_grad(out, &views.y, m, &mut dh);
        let has_embed = self.prog.embed.is_some();
        for li in (0..self.prog.layers.len()).rev() {
            let l = &self.prog.layers[li];
            let (k, n) = (l.in_dim, l.out_dim);
            let input: &[f32] = if li == 0 {
                if has_embed {
                    &fw.x0
                } else {
                    views.x
                }
            } else {
                &fw.acts[li - 1]
            };
            match l.act {
                Act::Linear => {}
                Act::Relu => ops::relu_backward(&fw.acts[li], &mut dh),
                Act::Sigmoid => ops::sigmoid_backward(&fw.acts[li], &mut dh),
            }
            if let Some(ln) = l.ln {
                let mut dgamma = vec![0.0f32; n];
                let mut dbeta = vec![0.0f32; n];
                ops::layernorm_backward(
                    &mut dh,
                    m,
                    n,
                    &params[ln.g_off..ln.g_off + n],
                    &fw.xhat[li],
                    &fw.rstd[li],
                    &mut dgamma,
                    &mut dbeta,
                );
                grad_out[ln.g_off..ln.g_off + n].copy_from_slice(&dgamma);
                on_segment(grad_out, ln.g_off, n);
                grad_out[ln.b_off..ln.b_off + n].copy_from_slice(&dbeta);
                on_segment(grad_out, ln.b_off, n);
            }
            if let Some(b_off) = l.b_off {
                ops::bias_db(&dh, m, n, &mut grad_out[b_off..b_off + n]);
                on_segment(grad_out, b_off, n);
            }
            ops::matmul_dw_ctx(
                ctx,
                input,
                &dh,
                m,
                k,
                n,
                &mut grad_out[l.w_off..l.w_off + l.w_len()],
            );
            on_segment(grad_out, l.w_off, l.w_len());
            if li > 0 || has_embed {
                let w = &params[l.w_off..l.w_off + l.w_len()];
                let mut dx = vec![0.0f32; m * k];
                ops::matmul_dx_ctx(ctx, &dh, w, m, k, n, &mut dx);
                dh = dx;
            }
        }
        if let Some(e) = &self.prog.embed {
            // The table streams last (offset 0 in the dlrm layout): its
            // scatter-add needs the fully backpropagated input gradient.
            ops::embedding_backward(
                &dh,
                views.cat.expect("embed program validated ids input"),
                m,
                e.fields,
                e.vocab,
                e.dim,
                e.dense_dim,
                &mut grad_out[e.t_off..e.t_off + e.t_len()],
            );
            on_segment(grad_out, e.t_off, e.t_len());
        }
        Ok(loss as f32)
    }

    /// Execute the artifact, producing outputs in manifest order.
    pub fn run(&self, spec: &ArtifactSpec, params: &[f32], batch: &Batch) -> Result<Vec<Array>> {
        let views = self.batch_views(batch)?;
        let m = views.m;
        if spec.kind == "train" {
            let mut grads = vec![0.0f32; self.prog.param_dim()];
            let loss = self.run_train_stream(params, batch, &mut grads, &mut |_, _, _| {})?;
            return Ok(vec![
                Array::F32(vec![loss], vec![]),
                Array::F32(grads, vec![self.prog.param_dim()]),
            ]);
        }
        // Eval graph: loss (+ per-example `correct`/`score` outputs).
        let fw = self.forward_ctx(params, &views, &ParallelCtx::serial());
        let out = fw.acts.last().expect("validated non-empty program");
        let mut scratch = vec![0.0f32; out.len()];
        let loss = self.loss_grad(out, &views.y, m, &mut scratch) as f32;
        let mut outs = vec![Array::F32(vec![loss], vec![])];
        if spec.outputs.len() > 1 {
            match (&self.prog.loss, spec.outputs[1].name.as_str()) {
                (Loss::SoftmaxXent { classes }, _) => {
                    let Labels::I32(y) = views.y else {
                        bail!("{}: classifier eval needs i32 labels", spec.name)
                    };
                    let mut correct = vec![0.0f32; m];
                    ops::argmax_correct(out, y, m, *classes, &mut correct);
                    outs.push(Array::F32(correct, vec![m]));
                }
                (Loss::SigmoidBce, "score") => {
                    // Predicted click probability σ(z) — the AUC input.
                    let score: Vec<f32> = out
                        .iter()
                        .map(|&z| (1.0 / (1.0 + (-(z as f64)).exp())) as f32)
                        .collect();
                    outs.push(Array::F32(score, vec![m]));
                }
                (Loss::SigmoidBce, _) => {
                    // Predicted class = σ(z) > 0.5 ⇔ z > 0.
                    let t_at = |i: usize| -> f32 {
                        match &views.y {
                            Labels::F32(y) => y[i],
                            Labels::I32(y) => y[i] as f32,
                            Labels::None => unreachable!("labels validated in new()"),
                        }
                    };
                    let correct: Vec<f32> = out
                        .iter()
                        .enumerate()
                        .map(|(i, &z)| (((z > 0.0) as i32 as f32) == t_at(i)) as i32 as f32)
                        .collect();
                    outs.push(Array::F32(correct, vec![m]));
                }
                _ => bail!(
                    "{}: eval outputs beyond loss need a classifier program",
                    spec.name
                ),
            }
        }
        Ok(outs)
    }
}

/// Deterministic parameter init for artifacts without init blobs: per
/// layer, weights ~ N(0, init_std) from a seed-keyed stream, biases zero;
/// the embedding table (when present) draws from its own fork, LN gammas
/// init to 1 and betas to 0. Independent of the artifact name so
/// linreg_b16/b64/b128 share inits, matching the aot.py behaviour (init
/// depends only on model + seed).
pub fn init_params(prog: &ProgramSpec, seed: u64) -> Vec<f32> {
    let mut p = vec![0.0f32; prog.param_dim()];
    if let Some(e) = &prog.embed {
        // Fork key far above any layer index, so the table stream never
        // collides with a layer's weight stream.
        let mut rng = Rng::new(seed.wrapping_add(0x5EED_1A17)).fork(0xE4BED);
        rng.fill_normal_f32(&mut p[e.t_off..e.t_off + e.t_len()], e.init_std);
    }
    for (li, l) in prog.layers.iter().enumerate() {
        let mut rng = Rng::new(seed.wrapping_add(0x5EED_1A17)).fork(li as u64);
        rng.fill_normal_f32(&mut p[l.w_off..l.w_off + l.w_len()], l.init_std);
        if let Some(ln) = l.ln {
            for v in &mut p[ln.g_off..ln.g_off + l.out_dim] {
                *v = 1.0;
            }
        }
    }
    p
}

/// The deterministic golden batch both `aot.py` and the Rust tests
/// regenerate bit-identically: f32 arrays filled with 0.5 (except f32
/// label arrays, which alternate 0/1 — BCE labels must be exact
/// indicators), int arrays `index % cardinality` (cardinality from the
/// artifact meta).
pub fn golden_batch(spec: &ArtifactSpec) -> Batch {
    spec.inputs
        .iter()
        .map(|io| {
            let n = io.numel();
            if io.dtype == "f32" {
                if io.name == "y" {
                    Array::F32(
                        (0..n).map(|i| (i % 2) as f32).collect(),
                        io.shape.clone(),
                    )
                } else {
                    Array::F32(vec![0.5; n], io.shape.clone())
                }
            } else {
                let card = match io.name.as_str() {
                    "y" => spec.meta.get("classes").as_usize().unwrap_or(2),
                    "cat" | "tokens" => spec.meta.get("vocab").as_usize().unwrap_or(2),
                    _ => 2,
                } as i64;
                Array::I32(
                    (0..n as i64).map(|i| (i % card) as i32).collect(),
                    io.shape.clone(),
                )
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ParallelPolicy;

    #[test]
    fn builtin_linreg_interprets_and_matches_reference() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("linreg_b16").unwrap();
        let exec = InterpExec::new(spec).unwrap();
        let params = spec.load_init(0).unwrap();
        let batch = golden_batch(spec);
        let outs = exec.run(spec, &params, &batch).unwrap();
        assert_eq!(outs.len(), 2);
        let golden = spec.golden.as_ref().unwrap();
        let loss = outs[0].as_f32().unwrap()[0] as f64;
        // Tolerance: interpreter stores f32 at layer boundaries but
        // accumulates in f64, so it sits within ~1e-6 relative of the
        // all-f64 reference; 1e-4 leaves margin.
        assert!(
            (loss - golden.loss).abs() / golden.loss.abs().max(1e-9) < 1e-4,
            "loss {loss} vs golden {}",
            golden.loss
        );
    }

    #[test]
    fn streamed_segments_cover_every_parameter_once() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        for name in ["mlp_cls_b32", "dlrm_lite"] {
            let spec = m.get(name).unwrap();
            let exec = InterpExec::new(spec).unwrap();
            let params = spec.load_init(0).unwrap();
            let batch = golden_batch(spec);
            let d = spec.param_dim;
            let mut grads = vec![0.0f32; d];
            let mut covered = vec![false; d];
            let mut offsets = Vec::new();
            let r = exec.run_train_stream(&params, &batch, &mut grads, &mut |_, off, len| {
                offsets.push(off);
                for c in &mut covered[off..off + len] {
                    assert!(!*c, "segment overlap at {off}");
                    *c = true;
                }
            });
            r.unwrap();
            assert!(
                covered.iter().all(|&c| c),
                "{name}: segments must tile the params"
            );
            // Backward order: later layers' blocks stream first.
            assert!(offsets.first().unwrap() > offsets.last().unwrap());
        }
    }

    #[test]
    fn streamed_grads_bitwise_identical_at_any_pool_width() {
        // The whole train step — embedding, layernorm, blocked matmuls,
        // pool-sharded backward — must produce bit-equal gradients with
        // 1, 2 and 5 lanes.
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        for name in ["mlp_cls_b32", "dlrm_lite"] {
            let spec = m.get(name).unwrap();
            let exec = InterpExec::new(spec).unwrap();
            let params = spec.load_init(0).unwrap();
            let batch = golden_batch(spec);
            let mut base = vec![0.0f32; spec.param_dim];
            let l0 = exec
                .run_train_stream(&params, &batch, &mut base, &mut |_, _, _| {})
                .unwrap();
            for threads in [2usize, 5] {
                let ctx = ParallelCtx::new(ParallelPolicy {
                    threads,
                    min_shard_elems: 1024,
                });
                let mut g = vec![0.0f32; spec.param_dim];
                let l = exec
                    .run_train_stream_ctx(&params, &batch, &mut g, &ctx, &mut |_, _, _| {})
                    .unwrap();
                assert_eq!(l0.to_bits(), l.to_bits(), "{name} loss @ {threads} lanes");
                assert!(
                    base.iter().zip(&g).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{name}: grads differ at {threads} lanes"
                );
            }
        }
    }

    #[test]
    fn dlrm_eval_emits_scores() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("dlrm_lite__eval").unwrap();
        let exec = InterpExec::new(spec).unwrap();
        let params = spec.load_init(0).unwrap();
        let batch = golden_batch(spec);
        let outs = exec.run(spec, &params, &batch).unwrap();
        assert_eq!(outs.len(), 2);
        let scores = outs[1].as_f32().unwrap();
        assert_eq!(scores.len(), spec.inputs[2].numel());
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
    }

    #[test]
    fn init_params_deterministic_and_layerwise() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("mlp_cls_b32").unwrap();
        let prog = spec.program.as_ref().unwrap();
        let a = init_params(prog, 0);
        let b = init_params(prog, 0);
        let c = init_params(prog, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Biases zero, weights non-trivial.
        let l0 = &prog.layers[0];
        let b_off = l0.b_off.unwrap();
        assert!(a[b_off..b_off + l0.out_dim].iter().all(|&v| v == 0.0));
        assert!(a[l0.w_off..l0.w_off + 8].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn init_params_covers_embed_table_and_ln() {
        let m = builtin::builtin_manifest(std::path::PathBuf::from("artifacts"));
        let spec = m.get("dlrm_lite").unwrap();
        let prog = spec.program.as_ref().unwrap();
        let p = init_params(prog, 0);
        let e = prog.embed.as_ref().unwrap();
        assert!(p[e.t_off..e.t_off + 16].iter().any(|&v| v != 0.0));
        let ln = prog.layers[0].ln.unwrap();
        let n = prog.layers[0].out_dim;
        assert!(p[ln.g_off..ln.g_off + n].iter().all(|&v| v == 1.0));
        assert!(p[ln.b_off..ln.b_off + n].iter().all(|&v| v == 0.0));
    }
}
