//! Hand-written fallback artifact specs for the interpreter backend.
//!
//! When no `artifacts/manifest.json` exists (the default offline
//! checkout), the runtime falls back to these specs so end-to-end
//! training runs with zero Python: the paper's linreg task (Eq. 14,
//! Fig. 2) at the three local batch sizes, and the MLP classifier
//! (Fig. 3 / Table 2 substitute). Shapes, dims, meta, and the flat
//! parameter layout (per layer: bias before weight, jax `ravel_pytree`
//! order) mirror `python/compile/manifest.py` exactly, so a later
//! `make artifacts` drop-in changes nothing downstream. Goldens are
//! minted by the f64 reference at load time ([`super::reference`]).

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::runtime::artifact::{ArtifactSpec, IoSpec, Manifest};
use crate::util::json::{num, obj, s};

use super::program::{Act, Dense, Embedding, LayerNorm, Loss, ProgramSpec};
use super::reference;

const LINREG_DIM: usize = 1000;
const MLP_IN: usize = 256;
const MLP_HIDDEN: usize = 512;
const MLP_CLASSES: usize = 16;
const MLP_TRAIN_BATCH: usize = 32;
const MLP_EVAL_BATCH: usize = 256;
// dlrm-lite: the CTR workload AdaSum motivates gradient-aware
// aggregation with — embedding-dominated params, tiny dense tower.
const DLRM_FIELDS: usize = 8;
const DLRM_VOCAB: usize = 1000;
const DLRM_EMB_DIM: usize = 16;
const DLRM_DENSE_DIM: usize = 16;
const DLRM_HIDDEN: [usize; 2] = [64, 32];
const DLRM_TRAIN_BATCH: usize = 64;
const DLRM_EVAL_BATCH: usize = 256;

fn f32_io(name: &str, shape: Vec<usize>) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        dtype: "f32".to_string(),
        shape,
    }
}

fn i32_io(name: &str, shape: Vec<usize>) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        dtype: "i32".to_string(),
        shape,
    }
}

fn linreg_program() -> ProgramSpec {
    ProgramSpec {
        embed: None,
        layers: vec![Dense {
            in_dim: LINREG_DIM,
            out_dim: 1,
            w_off: 0,
            b_off: None,
            ln: None,
            act: Act::Linear,
            // aot.py inits linreg from N(0, 1/sqrt(d)).
            init_std: (1.0 / (LINREG_DIM as f64).sqrt()) as f32,
        }],
        loss: Loss::MeanSquare,
    }
}

fn mlp_program() -> ProgramSpec {
    // jax ravel_pytree order over {l1:{b,w}, l2:{b,w}, l3:{b,w}}:
    // keys sort alphabetically, so each layer stores bias before weight.
    let dims = [(MLP_IN, MLP_HIDDEN), (MLP_HIDDEN, MLP_HIDDEN), (MLP_HIDDEN, MLP_CLASSES)];
    let mut layers = Vec::new();
    let mut off = 0usize;
    for (i, &(in_dim, out_dim)) in dims.iter().enumerate() {
        let b_off = off;
        let w_off = off + out_dim;
        off = w_off + in_dim * out_dim;
        layers.push(Dense {
            in_dim,
            out_dim,
            w_off,
            b_off: Some(b_off),
            ln: None,
            // He init on every layer, matching mlp.py's dense() helper.
            init_std: (2.0 / in_dim as f64).sqrt() as f32,
            act: if i + 1 < dims.len() { Act::Relu } else { Act::Linear },
        });
    }
    ProgramSpec {
        embed: None,
        layers,
        loss: Loss::SoftmaxXent { classes: MLP_CLASSES },
    }
}

fn dlrm_program() -> ProgramSpec {
    // Flat layout: the embedding table first, then per layer (jax
    // ravel_pytree alphabetical order over {b, ln_beta, ln_gamma, w})
    // bias, LN beta, LN gamma, weight. Hidden layers get relu + LN; the
    // final logit layer is plain linear.
    let embed = Embedding {
        fields: DLRM_FIELDS,
        vocab: DLRM_VOCAB,
        dim: DLRM_EMB_DIM,
        dense_dim: DLRM_DENSE_DIM,
        t_off: 0,
        init_std: 0.05,
    };
    let x_dim = embed.x_dim();
    let dims = [
        (x_dim, DLRM_HIDDEN[0]),
        (DLRM_HIDDEN[0], DLRM_HIDDEN[1]),
        (DLRM_HIDDEN[1], 1),
    ];
    let mut layers = Vec::new();
    let mut off = embed.t_len();
    for (i, &(in_dim, out_dim)) in dims.iter().enumerate() {
        let hidden = i + 1 < dims.len();
        let b_off = off;
        off += out_dim;
        let ln = if hidden {
            let ln = LayerNorm {
                b_off: off,
                g_off: off + out_dim,
            };
            off += 2 * out_dim;
            Some(ln)
        } else {
            None
        };
        let w_off = off;
        off += in_dim * out_dim;
        layers.push(Dense {
            in_dim,
            out_dim,
            w_off,
            b_off: Some(b_off),
            ln,
            init_std: (2.0 / in_dim as f64).sqrt() as f32,
            act: if hidden { Act::Relu } else { Act::Linear },
        });
    }
    ProgramSpec {
        embed: Some(embed),
        layers,
        loss: Loss::SigmoidBce,
    }
}

fn with_golden(mut spec: ArtifactSpec) -> ArtifactSpec {
    if spec.kind == "train" {
        let golden = reference::golden(&spec);
        spec.golden = Some(golden.expect("builtin goldens mint from static specs"));
    }
    spec
}

fn linreg_spec(dir: &std::path::Path, local_batch: usize, eval: bool) -> ArtifactSpec {
    let base = format!("linreg_b{local_batch}");
    let name = if eval { format!("{base}__eval") } else { base };
    let kind = if eval { "eval" } else { "train" };
    let prog = linreg_program();
    let outputs = if eval {
        vec![f32_io("loss", vec![])]
    } else {
        vec![f32_io("loss", vec![]), f32_io("grads", vec![LINREG_DIM])]
    };
    with_golden(ArtifactSpec {
        hlo_path: dir.join(format!("{name}.hlo.txt")),
        name,
        kind: kind.to_string(),
        model: "linreg".to_string(),
        param_dim: LINREG_DIM,
        inputs: vec![f32_io("x", vec![local_batch, LINREG_DIM])],
        outputs,
        init: BTreeMap::new(),
        golden: None,
        meta: obj(vec![
            ("model", s("linreg")),
            ("local_batch", num(local_batch as f64)),
            ("dim", num(LINREG_DIM as f64)),
        ]),
        program: Some(prog),
    })
}

fn mlp_spec(dir: &std::path::Path, eval: bool) -> ArtifactSpec {
    let name = if eval {
        format!("mlp_cls_b{MLP_TRAIN_BATCH}__eval")
    } else {
        format!("mlp_cls_b{MLP_TRAIN_BATCH}")
    };
    let kind = if eval { "eval" } else { "train" };
    let prog = mlp_program();
    let d = prog.param_dim();
    let b = if eval { MLP_EVAL_BATCH } else { MLP_TRAIN_BATCH };
    let outputs = if eval {
        vec![f32_io("loss", vec![]), f32_io("correct", vec![b])]
    } else {
        vec![f32_io("loss", vec![]), f32_io("grads", vec![d])]
    };
    with_golden(ArtifactSpec {
        hlo_path: dir.join(format!("{name}.hlo.txt")),
        name,
        kind: kind.to_string(),
        model: "mlp_cls".to_string(),
        param_dim: d,
        inputs: vec![f32_io("x", vec![b, MLP_IN]), i32_io("y", vec![b])],
        outputs,
        init: BTreeMap::new(),
        golden: None,
        meta: obj(vec![
            ("model", s("mlp_cls")),
            ("local_batch", num(MLP_TRAIN_BATCH as f64)),
            ("eval_batch", num(MLP_EVAL_BATCH as f64)),
            ("in_dim", num(MLP_IN as f64)),
            ("classes", num(MLP_CLASSES as f64)),
        ]),
        program: Some(prog),
    })
}

fn dlrm_spec(dir: &std::path::Path, eval: bool) -> ArtifactSpec {
    let name = if eval {
        "dlrm_lite__eval".to_string()
    } else {
        "dlrm_lite".to_string()
    };
    let kind = if eval { "eval" } else { "train" };
    let prog = dlrm_program();
    let d = prog.param_dim();
    let b = if eval { DLRM_EVAL_BATCH } else { DLRM_TRAIN_BATCH };
    let outputs = if eval {
        // `score` = σ(logit) per example: the AUC input the dlrm
        // evaluator pools (coordinator::eval).
        vec![f32_io("loss", vec![]), f32_io("score", vec![b])]
    } else {
        vec![f32_io("loss", vec![]), f32_io("grads", vec![d])]
    };
    with_golden(ArtifactSpec {
        hlo_path: dir.join(format!("{name}.hlo.txt")),
        name,
        kind: kind.to_string(),
        model: "dlrm".to_string(),
        param_dim: d,
        inputs: vec![
            i32_io("cat", vec![b, DLRM_FIELDS]),
            f32_io("dense", vec![b, DLRM_DENSE_DIM]),
            f32_io("y", vec![b]),
        ],
        outputs,
        init: BTreeMap::new(),
        golden: None,
        meta: obj(vec![
            ("model", s("dlrm")),
            ("local_batch", num(DLRM_TRAIN_BATCH as f64)),
            ("eval_batch", num(DLRM_EVAL_BATCH as f64)),
            ("fields", num(DLRM_FIELDS as f64)),
            ("vocab", num(DLRM_VOCAB as f64)),
            ("dense_dim", num(DLRM_DENSE_DIM as f64)),
            ("emb_dim", num(DLRM_EMB_DIM as f64)),
        ]),
        program: Some(prog),
    })
}

/// The fallback manifest: every interpretable artifact, goldens included.
pub fn builtin_manifest(dir: PathBuf) -> Manifest {
    let mut artifacts = BTreeMap::new();
    for lb in [16usize, 64, 128] {
        for eval in [false, true] {
            let spec = linreg_spec(&dir, lb, eval);
            artifacts.insert(spec.name.clone(), spec);
        }
    }
    for eval in [false, true] {
        let spec = mlp_spec(&dir, eval);
        artifacts.insert(spec.name.clone(), spec);
        let spec = dlrm_spec(&dir, eval);
        artifacts.insert(spec.name.clone(), spec);
    }
    Manifest {
        dir,
        artifacts,
        builtin: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_covers_the_paper_tasks() {
        let m = builtin_manifest(PathBuf::from("artifacts"));
        for name in [
            "linreg_b16",
            "linreg_b64",
            "linreg_b128",
            "linreg_b16__eval",
            "mlp_cls_b32",
            "mlp_cls_b32__eval",
        ] {
            assert!(m.get(name).is_ok(), "{name} missing");
        }
        let lin = m.get("linreg_b16").unwrap();
        assert_eq!(lin.param_dim, 1000);
        assert_eq!(lin.local_batch(), 16);
        assert_eq!(lin.inputs[0].shape, vec![16, 1000]);
        let mlp = m.get("mlp_cls_b32").unwrap();
        assert_eq!(mlp.param_dim, 402_448); // 3-layer 256-512-512-16 MLP
        assert_eq!(mlp.meta.get("classes").as_usize(), Some(16));
        let ev = m.get("mlp_cls_b32__eval").unwrap();
        assert_eq!(ev.kind, "eval");
        assert_eq!(ev.local_batch(), 256);
        assert_eq!(ev.outputs.len(), 2);
    }

    #[test]
    fn builtin_dlrm_layout_and_meta() {
        let m = builtin_manifest(PathBuf::from("artifacts"));
        let d = m.get("dlrm_lite").unwrap();
        // table 128000 + l0 (64b + 128ln + 9216w) + l1 (32b + 64ln +
        // 2048w) + l2 (1b + 32w)
        assert_eq!(d.param_dim, 139_585);
        assert_eq!(d.local_batch(), 64);
        assert_eq!(d.inputs[0].shape, vec![64, 8]);
        assert_eq!(d.inputs[1].shape, vec![64, 16]);
        assert_eq!(d.model, "dlrm");
        assert_eq!(d.meta.get("vocab").as_usize(), Some(1000));
        let prog = d.program.as_ref().unwrap();
        let e = prog.embed.as_ref().unwrap();
        assert_eq!(e.t_off, 0);
        assert_eq!(e.t_len(), 128_000);
        assert_eq!(prog.in_dim(), 144);
        assert!(prog.layers[0].ln.is_some() && prog.layers[2].ln.is_none());
        let ev = m.get("dlrm_lite__eval").unwrap();
        assert_eq!(ev.kind, "eval");
        assert_eq!(ev.outputs[1].name, "score");
        assert_eq!(ev.local_batch(), 256);
    }

    #[test]
    fn builtin_goldens_are_finite_and_plausible() {
        let m = builtin_manifest(PathBuf::from("artifacts"));
        for (name, spec) in &m.artifacts {
            if spec.kind != "train" {
                assert!(spec.golden.is_none(), "{name}");
                continue;
            }
            let g = spec.golden.as_ref().unwrap_or_else(|| panic!("{name} golden"));
            assert!(g.loss.is_finite() && g.loss > 0.0, "{name} loss {}", g.loss);
            assert!(g.grad_l2.is_finite() && g.grad_l2 > 0.0, "{name}");
            assert!(g.grad_sum.is_finite(), "{name}");
        }
        // The MLP starts near chance: loss ~ ln(16).
        let g = m.get("mlp_cls_b32").unwrap().golden.clone().unwrap();
        assert!(
            (g.loss - (16.0f64).ln()).abs() < 1.0,
            "mlp golden loss {} far from ln(16)",
            g.loss
        );
    }

    #[test]
    fn builtin_inits_load_for_any_seed() {
        let m = builtin_manifest(PathBuf::from("artifacts"));
        let lin = m.get("linreg_b64").unwrap();
        let p0 = lin.load_init(0).unwrap();
        let p1 = lin.load_init(1).unwrap();
        assert_eq!(p0.len(), 1000);
        assert_ne!(p0, p1);
        assert!(p0.iter().all(|v| v.is_finite()));
        // Same model, different batch size: identical init (aot parity).
        let lin16 = m.get("linreg_b16").unwrap();
        assert_eq!(lin16.load_init(0).unwrap(), p0);
    }
}
