//! `ProgramSpec` — the per-artifact program description that drives the
//! native interpreter backend.
//!
//! A program is a feed-forward chain of dense layers (matmul + optional
//! bias + activation) followed by a loss. Each layer names the *offsets*
//! of its weight/bias blocks inside the flat parameter vector, so the
//! interpreter is layout-agnostic: `python/compile/aot.py` emits offsets
//! matching jax's `ravel_pytree` order (per layer: bias before weight),
//! and the hand-written fallback specs in [`super::builtin`] use the same
//! convention so a later `make artifacts` run stays init-blob compatible.

use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;

/// Elementwise activation applied after the affine map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Linear,
    Relu,
    Sigmoid,
}

impl Act {
    pub fn parse(s: &str) -> Option<Act> {
        match s {
            "none" | "linear" => Some(Act::Linear),
            "relu" => Some(Act::Relu),
            "sigmoid" => Some(Act::Sigmoid),
            _ => None,
        }
    }
}

/// Per-layer LayerNorm applied between the affine map and the
/// activation: `h = act(γ ⊙ norm(x @ W + b) + β)`. Gamma/beta are
/// `out_dim`-long parameter blocks; gamma inits to 1, beta to 0 (no
/// `init_std` needed — the init is deterministic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerNorm {
    /// Offset of the gamma (scale) block, `out_dim` long.
    pub g_off: usize,
    /// Offset of the beta (shift) block, `out_dim` long.
    pub b_off: usize,
}

/// One dense layer: `h = act(ln?(x @ W + b))` with `W` stored row-major
/// `(in_dim, out_dim)` at `w_off` and `b` (when present) at `b_off`.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    pub in_dim: usize,
    pub out_dim: usize,
    pub w_off: usize,
    pub b_off: Option<usize>,
    /// Optional LayerNorm between the affine map and the activation.
    pub ln: Option<LayerNorm>,
    pub act: Act,
    /// Weight-init std used when the artifact has no init blobs (builtin
    /// fallback path); biases init to zero.
    pub init_std: f32,
}

impl Dense {
    pub fn w_len(&self) -> usize {
        self.in_dim * self.out_dim
    }
}

/// Embedding front-end (the dlrm-style input layer): `fields` stacked
/// `(vocab, dim)` tables at `t_off` gather per-field id rows which are
/// concatenated with the dense features to form the first layer's input
/// (`x0[i,:] = emb(cat[i,0]) ++ … ++ emb(cat[i,F-1]) ++ dense[i,:]`).
#[derive(Debug, Clone, PartialEq)]
pub struct Embedding {
    pub fields: usize,
    pub vocab: usize,
    /// Per-field embedding row width.
    pub dim: usize,
    /// Dense (continuous) feature count appended after the embeddings.
    pub dense_dim: usize,
    /// Offset of the stacked table block, `fields·vocab·dim` long.
    pub t_off: usize,
    /// Table-init std for the blob-less builtin path.
    pub init_std: f32,
}

impl Embedding {
    pub fn t_len(&self) -> usize {
        self.fields * self.vocab * self.dim
    }

    /// Width of the assembled first-layer input row.
    pub fn x_dim(&self) -> usize {
        self.fields * self.dim + self.dense_dim
    }
}

/// The scalar training loss applied to the final layer output.
#[derive(Debug, Clone, PartialEq)]
pub enum Loss {
    /// `mean_b 0.5 * ||y_b||^2` — the paper's Eq. 14 stochastic linear
    /// regression objective (MSE against a zero target).
    MeanSquare,
    /// Mean softmax cross-entropy over `classes` logits with i32 labels.
    SoftmaxXent { classes: usize },
    /// Mean sigmoid binary cross-entropy over a single logit with f32
    /// {0,1} labels — the CTR/detection head (final layer out dim must
    /// be 1).
    SigmoidBce,
}

/// A complete interpretable program for one artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramSpec {
    /// Optional embedding front-end assembling the first layer's input.
    pub embed: Option<Embedding>,
    pub layers: Vec<Dense>,
    pub loss: Loss,
}

impl ProgramSpec {
    /// Parse the manifest's `program` record.
    ///
    /// ```json
    /// {"layers": [{"in": 256, "out": 512, "w_off": 512, "b_off": 0,
    ///              "act": "relu", "init_std": 0.088}],
    ///  "loss": {"kind": "softmax_xent", "classes": 16}}
    /// ```
    pub fn from_json(j: &Json) -> Result<ProgramSpec> {
        let mut layers = Vec::new();
        for (i, l) in j.get("layers").as_arr().context("program layers")?.iter().enumerate() {
            let in_dim = l.get("in").as_usize().with_context(|| format!("layer {i} in"))?;
            let out_dim = l.get("out").as_usize().with_context(|| format!("layer {i} out"))?;
            let act = match l.get("act").as_str() {
                None => Act::Linear,
                Some(s) => Act::parse(s).with_context(|| format!("layer {i}: bad act {s:?}"))?,
            };
            let lnj = l.get("ln");
            let ln = match lnj.get("g_off").as_usize() {
                None => None,
                Some(g_off) => Some(LayerNorm {
                    g_off,
                    b_off: lnj.get("b_off").as_usize().with_context(|| format!("layer {i} ln b_off"))?,
                }),
            };
            layers.push(Dense {
                in_dim,
                out_dim,
                w_off: l.get("w_off").as_usize().with_context(|| format!("layer {i} w_off"))?,
                b_off: l.get("b_off").as_usize(),
                ln,
                act,
                init_std: l.get("init_std").as_f64().unwrap_or(0.0) as f32,
            });
        }
        let ej = j.get("embed");
        let embed = match ej.get("fields").as_usize() {
            None => None,
            Some(fields) => Some(Embedding {
                fields,
                vocab: ej.get("vocab").as_usize().context("embed vocab")?,
                dim: ej.get("dim").as_usize().context("embed dim")?,
                dense_dim: ej.get("dense_dim").as_usize().context("embed dense_dim")?,
                t_off: ej.get("t_off").as_usize().context("embed t_off")?,
                init_std: ej.get("init_std").as_f64().unwrap_or(0.0) as f32,
            }),
        };
        let lj = j.get("loss");
        let loss = match lj.get("kind").as_str() {
            Some("mean_square") => Loss::MeanSquare,
            Some("softmax_xent") => Loss::SoftmaxXent {
                classes: lj.get("classes").as_usize().context("softmax_xent classes")?,
            },
            Some("sigmoid_bce") => Loss::SigmoidBce,
            other => bail!("program loss kind {other:?} not supported"),
        };
        let p = ProgramSpec { embed, layers, loss };
        p.validate()?;
        Ok(p)
    }

    /// Batch-input feature dim of the first layer.
    pub fn in_dim(&self) -> usize {
        self.layers.first().map(|l| l.in_dim).unwrap_or(0)
    }

    /// Output dim of the last layer.
    pub fn out_dim(&self) -> usize {
        self.layers.last().map(|l| l.out_dim).unwrap_or(0)
    }

    /// The parameter blocks `(offset, len)` in flat-vector order.
    pub fn param_blocks(&self) -> Vec<(usize, usize)> {
        let mut blocks = Vec::with_capacity(4 * self.layers.len() + 1);
        if let Some(e) = &self.embed {
            blocks.push((e.t_off, e.t_len()));
        }
        for l in &self.layers {
            blocks.push((l.w_off, l.w_len()));
            if let Some(b) = l.b_off {
                blocks.push((b, l.out_dim));
            }
            if let Some(ln) = l.ln {
                blocks.push((ln.g_off, l.out_dim));
                blocks.push((ln.b_off, l.out_dim));
            }
        }
        blocks.sort_unstable();
        blocks
    }

    /// Total parameter count implied by the blocks.
    pub fn param_dim(&self) -> usize {
        self.param_blocks().iter().map(|&(o, l)| o + l).max().unwrap_or(0)
    }

    /// Structural checks: non-empty, layer dims chain, blocks tile the
    /// flat vector exactly (the streaming backward path relies on full
    /// coverage to complete every gradient bucket).
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("program has no layers");
        }
        for (i, w) in self.layers.windows(2).enumerate() {
            if w[0].out_dim != w[1].in_dim {
                bail!(
                    "program layer {i} out {} != layer {} in {}",
                    w[0].out_dim,
                    i + 1,
                    w[1].in_dim
                );
            }
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.in_dim == 0 || l.out_dim == 0 {
                bail!("program layer {i} has a zero dim");
            }
        }
        if let Some(e) = &self.embed {
            if e.fields == 0 || e.vocab == 0 || e.dim == 0 {
                bail!("program embed has a zero dim (fields/vocab/dim)");
            }
            if self.in_dim() != e.x_dim() {
                bail!(
                    "embed output width {} (fields*dim + dense_dim) != first layer in {}",
                    e.x_dim(),
                    self.in_dim()
                );
            }
        }
        if let Loss::SoftmaxXent { classes } = self.loss {
            if classes != self.out_dim() {
                bail!(
                    "softmax_xent classes {classes} != final layer out {}",
                    self.out_dim()
                );
            }
        }
        if self.loss == Loss::SigmoidBce && self.out_dim() != 1 {
            bail!(
                "sigmoid_bce needs a single output logit, final layer out is {}",
                self.out_dim()
            );
        }
        let blocks = self.param_blocks();
        let mut cursor = 0usize;
        for &(off, len) in &blocks {
            if off != cursor {
                bail!(
                    "program param blocks must tile [0, d) exactly: \
                     gap/overlap at offset {off} (expected {cursor})"
                );
            }
            cursor = off + len;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp_json() -> Json {
        Json::parse(
            r#"{"layers": [
                 {"in": 4, "out": 3, "w_off": 3, "b_off": 0, "act": "relu"},
                 {"in": 3, "out": 2, "w_off": 17, "b_off": 15, "act": "none"}],
                "loss": {"kind": "softmax_xent", "classes": 2}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates_mlp() {
        let p = ProgramSpec::from_json(&mlp_json()).unwrap();
        assert_eq!(p.layers.len(), 2);
        assert_eq!(p.layers[0].act, Act::Relu);
        assert_eq!(p.param_dim(), 3 + 12 + 2 + 6);
        assert_eq!(p.in_dim(), 4);
        assert_eq!(p.out_dim(), 2);
    }

    #[test]
    fn rejects_dim_mismatch_and_gaps() {
        let mut p = ProgramSpec::from_json(&mlp_json()).unwrap();
        p.layers[1].in_dim = 5;
        assert!(p.validate().is_err());
        let mut p = ProgramSpec::from_json(&mlp_json()).unwrap();
        p.layers[1].w_off = 18; // leaves a gap at 17
        assert!(p.validate().is_err());
        let mut p = ProgramSpec::from_json(&mlp_json()).unwrap();
        p.loss = Loss::SoftmaxXent { classes: 5 };
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_bad_loss_kind() {
        let j = Json::parse(
            r#"{"layers": [{"in": 2, "out": 1, "w_off": 0}],
                "loss": {"kind": "hinge"}}"#,
        )
        .unwrap();
        assert!(ProgramSpec::from_json(&j).is_err());
    }

    #[test]
    fn sigmoid_bce_parses_and_requires_single_logit() {
        let j = Json::parse(
            r#"{"layers": [{"in": 8, "out": 1, "w_off": 1, "b_off": 0}],
                "loss": {"kind": "sigmoid_bce"}}"#,
        )
        .unwrap();
        let p = ProgramSpec::from_json(&j).unwrap();
        assert_eq!(p.loss, Loss::SigmoidBce);
        assert_eq!(p.param_dim(), 9);
        let j = Json::parse(
            r#"{"layers": [{"in": 8, "out": 2, "w_off": 2, "b_off": 0}],
                "loss": {"kind": "sigmoid_bce"}}"#,
        )
        .unwrap();
        assert!(ProgramSpec::from_json(&j).is_err());
    }

    #[test]
    fn embed_and_ln_parse_and_tile() {
        // table 0..12, bias 12..14, ln beta 14..16, ln gamma 16..18,
        // weight 18..28 — blocks must tile [0, 28) exactly.
        let j = Json::parse(
            r#"{"embed": {"fields": 2, "vocab": 3, "dim": 2, "dense_dim": 1,
                          "t_off": 0, "init_std": 0.05},
                "layers": [{"in": 5, "out": 2, "w_off": 18, "b_off": 12,
                            "ln": {"g_off": 16, "b_off": 14}, "act": "relu"}],
                "loss": {"kind": "mean_square"}}"#,
        )
        .unwrap();
        let p = ProgramSpec::from_json(&j).unwrap();
        let e = p.embed.as_ref().unwrap();
        assert_eq!((e.fields, e.vocab, e.dim, e.dense_dim), (2, 3, 2, 1));
        assert_eq!(e.t_len(), 12);
        assert_eq!(e.x_dim(), 5);
        assert_eq!(p.layers[0].ln, Some(LayerNorm { g_off: 16, b_off: 14 }));
        assert_eq!(p.param_dim(), 28);
    }

    #[test]
    fn embed_width_must_match_first_layer() {
        let j = Json::parse(
            r#"{"embed": {"fields": 2, "vocab": 3, "dim": 2, "dense_dim": 1,
                          "t_off": 0},
                "layers": [{"in": 4, "out": 1, "w_off": 12}],
                "loss": {"kind": "mean_square"}}"#,
        )
        .unwrap();
        assert!(ProgramSpec::from_json(&j).is_err());
    }

    #[test]
    fn linreg_shape() {
        let j = Json::parse(
            r#"{"layers": [{"in": 1000, "out": 1, "w_off": 0, "init_std": 0.0316}],
                "loss": {"kind": "mean_square"}}"#,
        )
        .unwrap();
        let p = ProgramSpec::from_json(&j).unwrap();
        assert_eq!(p.param_dim(), 1000);
        assert!(p.layers[0].b_off.is_none());
    }
}
