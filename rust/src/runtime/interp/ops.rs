//! Interpreter op kernels: matmul, bias add, relu/sigmoid, mean-square
//! and softmax-xent losses, and their backward ops.
//!
//! All kernels store f32 (matching the PJRT artifacts' dtype contract)
//! but accumulate in f64, so the interpreter's results sit within f32
//! rounding of the straight-line f64 reference (`super::reference`) —
//! that is what makes the tight golden tolerances in
//! `tests/runtime_golden.rs` and the finite-difference checks in
//! `tests/interp_grad_check.rs` possible.

/// `out = x @ w`: `x` is `(m, k)` row-major, `w` is `(k, n)` row-major.
/// Accumulates each output row in an f64 buffer (inner loop runs over the
/// contiguous `n` axis, so it vectorizes).
pub fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut row = vec![0.0f64; n];
    for i in 0..m {
        row.iter_mut().for_each(|r| *r = 0.0);
        for kk in 0..k {
            let xv = x[i * k + kk] as f64;
            if xv == 0.0 {
                continue; // post-relu inputs are ~half zeros
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for (r, &wv) in row.iter_mut().zip(wrow) {
                *r += xv * wv as f64;
            }
        }
        for (o, &r) in out[i * n..(i + 1) * n].iter_mut().zip(&row) {
            *o = r as f32;
        }
    }
}

/// `h[i, :] += b` for every row.
pub fn bias_add(h: &mut [f32], m: usize, n: usize, b: &[f32]) {
    debug_assert_eq!(h.len(), m * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..m {
        for (hv, &bv) in h[i * n..(i + 1) * n].iter_mut().zip(b) {
            *hv += bv;
        }
    }
}

/// In-place `max(x, 0)`.
pub fn relu(h: &mut [f32]) {
    for v in h.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place logistic sigmoid (computed in f64 per element).
pub fn sigmoid(h: &mut [f32]) {
    for v in h.iter_mut() {
        *v = (1.0 / (1.0 + (-(*v as f64)).exp())) as f32;
    }
}

/// Backward of relu given the *post-activation* values: `dh *= 1[h > 0]`
/// (subgradient 0 at the kink, matching jax's `max` VJP at 0 inputs).
pub fn relu_backward(h: &[f32], dh: &mut [f32]) {
    debug_assert_eq!(h.len(), dh.len());
    for (d, &hv) in dh.iter_mut().zip(h) {
        if hv <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Backward of sigmoid given the post-activation values: `dh *= s(1-s)`.
pub fn sigmoid_backward(h: &[f32], dh: &mut [f32]) {
    debug_assert_eq!(h.len(), dh.len());
    for (d, &s) in dh.iter_mut().zip(h) {
        let s = s as f64;
        *d = (*d as f64 * s * (1.0 - s)) as f32;
    }
}

/// Weight gradient `dw = x^T @ dz`: `x` is `(m, k)`, `dz` is `(m, n)`,
/// `dw` out is `(k, n)` row-major. f64 accumulator matrix.
pub fn matmul_dw(x: &[f32], dz: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    let mut acc = vec![0.0f64; k * n];
    for i in 0..m {
        let dzrow = &dz[i * n..(i + 1) * n];
        for kk in 0..k {
            let xv = x[i * k + kk] as f64;
            if xv == 0.0 {
                continue;
            }
            let arow = &mut acc[kk * n..(kk + 1) * n];
            for (a, &dv) in arow.iter_mut().zip(dzrow) {
                *a += xv * dv as f64;
            }
        }
    }
    for (o, &a) in dw.iter_mut().zip(&acc) {
        *o = a as f32;
    }
}

/// Input gradient `dx = dz @ w^T`: `dz` is `(m, n)`, `w` is `(k, n)`,
/// `dx` out is `(m, k)`. Each element is a contiguous f64 dot over `n`.
pub fn matmul_dx(dz: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    for i in 0..m {
        let dzrow = &dz[i * n..(i + 1) * n];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f64;
            for (&dv, &wv) in dzrow.iter().zip(wrow) {
                acc += dv as f64 * wv as f64;
            }
            dx[i * k + kk] = acc as f32;
        }
    }
}

/// Bias gradient `db = sum_rows(dz)` with f64 column accumulators.
pub fn bias_db(dz: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(db.len(), n);
    let mut acc = vec![0.0f64; n];
    for i in 0..m {
        for (a, &dv) in acc.iter_mut().zip(&dz[i * n..(i + 1) * n]) {
            *a += dv as f64;
        }
    }
    for (o, &a) in db.iter_mut().zip(&acc) {
        *o = a as f32;
    }
}

/// Mean-square loss `mean_b 0.5*||y_b||^2` over `(m, n)` outputs.
/// Returns the f64 loss and writes `dy = y / m`.
pub fn mean_square_loss(y: &[f32], m: usize, n: usize, dy: &mut [f32]) -> f64 {
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(dy.len(), m * n);
    let inv_m = 1.0 / m as f64;
    let mut acc = 0.0f64;
    for (&v, d) in y.iter().zip(dy.iter_mut()) {
        let v = v as f64;
        acc += v * v;
        *d = (v * inv_m) as f32;
    }
    0.5 * acc * inv_m
}

/// Mean softmax cross-entropy over `(m, c)` logits with i32 labels.
/// Per-row log-sum-exp runs in f64 (max-shifted, so large logits cannot
/// overflow). Returns the f64 loss and writes
/// `dlogits = (softmax - onehot(y)) / m`.
pub fn softmax_xent_loss(logits: &[f32], y: &[i32], m: usize, c: usize, dl: &mut [f32]) -> f64 {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(y.len(), m);
    debug_assert_eq!(dl.len(), m * c);
    let inv_m = 1.0 / m as f64;
    let mut loss = 0.0f64;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let label = y[i] as usize;
        debug_assert!(label < c, "label {label} out of range (classes {c})");
        let mx = row.iter().fold(f64::NEG_INFINITY, |a, &v| a.max(v as f64));
        let mut z = 0.0f64;
        for &v in row {
            z += (v as f64 - mx).exp();
        }
        let lse = mx + z.ln();
        loss += lse - row[label] as f64;
        let drow = &mut dl[i * c..(i + 1) * c];
        for (j, (d, &v)) in drow.iter_mut().zip(row).enumerate() {
            let p = (v as f64 - mx).exp() / z;
            let target = if j == label { 1.0 } else { 0.0 };
            *d = ((p - target) * inv_m) as f32;
        }
    }
    loss * inv_m
}

/// Mean sigmoid binary-cross-entropy over `(m, 1)` logits with i32 {0,1}
/// labels — the CTR/detection-head loss (first step toward the det/dlrm
/// artifacts running on the interpreter). Per element, in f64:
/// `max(z,0) - z·y + ln(1 + e^{-|z|})` (the overflow-free softplus form
/// of `-y·ln σ(z) - (1-y)·ln(1-σ(z))`). Returns the f64 loss and writes
/// `dz = (σ(z) - y) / m`.
pub fn sigmoid_bce_loss(logits: &[f32], y: &[i32], m: usize, dl: &mut [f32]) -> f64 {
    debug_assert_eq!(logits.len(), m);
    debug_assert_eq!(y.len(), m);
    debug_assert_eq!(dl.len(), m);
    let inv_m = 1.0 / m as f64;
    let mut loss = 0.0f64;
    for i in 0..m {
        let z = logits[i] as f64;
        let t = y[i] as f64;
        // Hard assert (not debug): an out-of-range label would silently
        // corrupt loss and gradients in release builds (unlike
        // softmax_xent, whose bad label panics on the row index).
        assert!(y[i] == 0 || y[i] == 1, "BCE label must be 0/1, got {}", y[i]);
        loss += z.max(0.0) - z * t + (-z.abs()).exp().ln_1p();
        let s = 1.0 / (1.0 + (-z).exp());
        dl[i] = ((s - t) * inv_m) as f32;
    }
    loss * inv_m
}

/// Per-row argmax == label indicator (the `correct` eval output of the
/// classifier artifacts; ties resolve to the lowest index, like argmax).
pub fn argmax_correct(logits: &[f32], y: &[i32], m: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(out.len(), m);
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        out[i] = if best as i32 == y[i] { 1.0 } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_exact() {
        // (2,3) @ (3,2)
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut out = [0.0f32; 4];
        matmul(&x, 2, 3, &w, 2, &mut out);
        assert_eq!(out, [4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn bias_relu_sigmoid_roundtrip() {
        let mut h = [-1.0f32, 0.5, -0.25, 2.0];
        bias_add(&mut h, 2, 2, &[0.25, -0.5]);
        assert_eq!(h, [-0.75, 0.0, 0.0, 1.5]);
        let mut r = h;
        relu(&mut r);
        assert_eq!(r, [0.0, 0.0, 0.0, 1.5]);
        let mut s = [0.0f32];
        sigmoid(&mut s);
        assert!((s[0] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let logits = [0.0f32; 6]; // (2, 3) uniform
        let y = [0i32, 2];
        let mut dl = [0.0f32; 6];
        let loss = softmax_xent_loss(&logits, &y, 2, 3, &mut dl);
        assert!((loss - (3.0f64).ln()).abs() < 1e-12);
        // Gradient rows sum to zero and the label entry is negative.
        assert!((dl[0] - (1.0 / 3.0 - 1.0) as f32 / 2.0).abs() < 1e-6);
        let row_sum: f32 = dl[..3].iter().sum();
        assert!(row_sum.abs() < 1e-6);
    }

    #[test]
    fn mean_square_matches_hand_value() {
        let y = [1.0f32, -2.0, 3.0, 0.0]; // (2, 2)
        let mut dy = [0.0f32; 4];
        let loss = mean_square_loss(&y, 2, 2, &mut dy);
        assert!((loss - 0.5 * (1.0 + 4.0 + 9.0) / 2.0).abs() < 1e-12);
        assert_eq!(dy, [0.5, -1.0, 1.5, 0.0]);
    }

    #[test]
    fn sigmoid_bce_hand_values_and_stability() {
        // z = 0: loss = ln 2 per element regardless of label; dz = ±0.5/m.
        let logits = [0.0f32, 0.0];
        let mut dl = [0.0f32; 2];
        let loss = sigmoid_bce_loss(&logits, &[1, 0], 2, &mut dl);
        assert!((loss - (2.0f64).ln()).abs() < 1e-12);
        assert!((dl[0] + 0.25).abs() < 1e-7);
        assert!((dl[1] - 0.25).abs() < 1e-7);
        // Confident-correct: near-zero loss; confident-wrong: ~|z|.
        let logits = [30.0f32, -30.0];
        let loss = sigmoid_bce_loss(&logits, &[1, 0], 2, &mut dl);
        assert!(loss < 1e-10, "{loss}");
        let loss = sigmoid_bce_loss(&logits, &[0, 1], 2, &mut dl);
        assert!((loss - 30.0).abs() < 1e-6, "{loss}");
        // Huge logits stay finite (softplus form cannot overflow).
        let logits = [500.0f32, -500.0];
        let loss = sigmoid_bce_loss(&logits, &[0, 1], 2, &mut dl);
        assert!(loss.is_finite() && dl.iter().all(|d| d.is_finite()));
    }

    #[test]
    fn argmax_correct_handles_ties_low() {
        let logits = [1.0f32, 1.0, 0.0, 0.5, 2.0, 0.5]; // (2, 3)
        let mut out = [9.0f32; 2];
        argmax_correct(&logits, &[0, 1], 2, 3, &mut out);
        assert_eq!(out, [1.0, 1.0]);
        argmax_correct(&logits, &[1, 0], 2, 3, &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }
}
