//! Interpreter op kernels: blocked matmul forward/backward, bias add,
//! relu/sigmoid, embedding lookup, layernorm, mean-square / softmax-xent
//! / sigmoid-BCE losses, and their backward ops.
//!
//! All kernels store f32 (matching the PJRT artifacts' dtype contract)
//! but accumulate in f64, so the interpreter's results sit within f32
//! rounding of the straight-line f64 reference (`super::reference`) —
//! that is what makes the tight golden tolerances in
//! `tests/runtime_golden.rs` and the finite-difference checks in
//! `tests/interp_grad_check.rs` possible.
//!
//! # Determinism contract
//!
//! Every output element of every kernel is produced by **one f64
//! accumulator fed in a fixed canonical order** that never depends on
//! tiling, blocking, or the thread count:
//!
//! * `matmul`    — `out[i,j] = Σ_kk x[i,kk]·w[kk,j]`, `kk` ascending;
//! * `matmul_dw` — `dw[kk,j] = Σ_i  x[i,kk]·dz[i,j]`, `i` ascending;
//! * `matmul_dx` — `dx[i,kk] = Σ_j dz[i,j]·w[kk,j]`, `j` ascending.
//!
//! The blocked kernels below reorder only *which elements* are in
//! flight together (register tiles the autovectorizer can chew on);
//! the per-element addition sequence is untouched. The `_ctx` variants
//! shard **disjoint output bands** (rows of `out`/`dx`, `kk`-bands of
//! `dw`) over the worker pool — no partial-sum combine exists anywhere,
//! so results are bitwise-identical to the serial kernels and to the
//! [`oracle`] scalar loops at every thread count. The `oracle` module is
//! the always-compiled ground truth the kernel-equivalence suite and the
//! bench microbenchmarks compare against.
//!
//! Zero inputs are **not** skipped: `0 × inf` and `0 × NaN` are `NaN`,
//! and a poisoned weight must poison the output (a NaN-injection rank
//! must be observable downstream), so the kernels are NaN-transparent.

use crate::parallel::{Job, ParallelCtx};

/// Row tile: output rows sharing one sweep of the `w`/`dz` operand.
const MB: usize = 4;
/// Column tile for the forward matmul's f64 accumulator block
/// (`MB × NB × 8 B` = 2 KiB — lives in registers / L1).
const NB: usize = 64;
/// Below this many products (`m·k·n`) the `_ctx` kernels stay serial:
/// pool dispatch costs more than the tile work saves.
const PAR_MIN_PRODUCTS: usize = 64 * 1024;

/// LayerNorm variance epsilon (shared with `super::reference`).
pub const LN_EPS: f64 = 1e-5;

/// Scalar reference kernels: one f64 accumulator per output element, fed
/// in the canonical order documented on the module. Always compiled (not
/// `#[cfg(test)]`) so the kernel-equivalence integration suite and the
/// matmul microbenchmarks can call them from outside the crate.
pub mod oracle {
    /// `out = x @ w`, per element `kk`-ascending.
    pub fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), m * k);
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(out.len(), m * n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += x[i * k + kk] as f64 * w[kk * n + j] as f64;
                }
                out[i * n + j] = acc as f32;
            }
        }
    }

    /// `dw = x^T @ dz`, per element `i`-ascending.
    pub fn matmul_dw(x: &[f32], dz: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
        debug_assert_eq!(x.len(), m * k);
        debug_assert_eq!(dz.len(), m * n);
        debug_assert_eq!(dw.len(), k * n);
        for kk in 0..k {
            for j in 0..n {
                let mut acc = 0.0f64;
                for i in 0..m {
                    acc += x[i * k + kk] as f64 * dz[i * n + j] as f64;
                }
                dw[kk * n + j] = acc as f32;
            }
        }
    }

    /// `dx = dz @ w^T`, per element `j`-ascending.
    pub fn matmul_dx(dz: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
        debug_assert_eq!(dz.len(), m * n);
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(dx.len(), m * k);
        for i in 0..m {
            for kk in 0..k {
                let mut acc = 0.0f64;
                for j in 0..n {
                    acc += dz[i * n + j] as f64 * w[kk * n + j] as f64;
                }
                dx[i * k + kk] = acc as f32;
            }
        }
    }
}

/// `out = x @ w`: `x` is `(m, k)` row-major, `w` is `(k, n)` row-major.
///
/// Register-tiled: `MB` output rows × `NB` output columns accumulate in
/// a stack f64 block while one `kk`-sweep streams the shared `w` row
/// tile past all `MB` rows. Per-element accumulation order is
/// `kk`-ascending — bitwise equal to [`oracle::matmul`].
pub fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut i0 = 0;
    while i0 < m {
        let rb = MB.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let jw = NB.min(n - j0);
            let mut acc = [[0.0f64; NB]; MB];
            for kk in 0..k {
                let wtile = &w[kk * n + j0..kk * n + j0 + jw];
                for (r, arow) in acc.iter_mut().enumerate().take(rb) {
                    let xv = x[(i0 + r) * k + kk] as f64;
                    for (a, &wv) in arow[..jw].iter_mut().zip(wtile) {
                        *a += xv * wv as f64;
                    }
                }
            }
            for (r, arow) in acc.iter().enumerate().take(rb) {
                let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
                for (o, &a) in orow.iter_mut().zip(&arow[..jw]) {
                    *o = a as f32;
                }
            }
            j0 += jw;
        }
        i0 += rb;
    }
}

/// Forward matmul sharded by output **rows** over the pool. Each job
/// owns a disjoint `out` band and runs the blocked kernel on its rows —
/// no combine, so bitwise-identical to [`matmul`] at any thread count.
pub fn matmul_ctx(
    ctx: &ParallelCtx,
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    let bands = row_bands(m, ctx.threads(), m * k * n);
    if bands.len() <= 1 {
        matmul(x, m, k, w, n, out);
        return;
    }
    let width = bands[0].1 - bands[0].0;
    let jobs: Vec<Job<'_>> = out
        .chunks_mut(width * n)
        .zip(&bands)
        .map(|(oc, &(a, b))| {
            let xs = &x[a * k..b * k];
            Box::new(move || matmul(xs, b - a, k, w, n, oc)) as Job<'_>
        })
        .collect();
    ctx.run(jobs);
}

/// `h[i, :] += b` for every row.
pub fn bias_add(h: &mut [f32], m: usize, n: usize, b: &[f32]) {
    debug_assert_eq!(h.len(), m * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..m {
        for (hv, &bv) in h[i * n..(i + 1) * n].iter_mut().zip(b) {
            *hv += bv;
        }
    }
}

/// In-place `max(x, 0)`.
pub fn relu(h: &mut [f32]) {
    for v in h.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place logistic sigmoid (computed in f64 per element).
pub fn sigmoid(h: &mut [f32]) {
    for v in h.iter_mut() {
        *v = (1.0 / (1.0 + (-(*v as f64)).exp())) as f32;
    }
}

/// Backward of relu given the *post-activation* values: `dh *= 1[h > 0]`
/// (subgradient 0 at the kink, matching jax's `max` VJP at 0 inputs).
pub fn relu_backward(h: &[f32], dh: &mut [f32]) {
    debug_assert_eq!(h.len(), dh.len());
    for (d, &hv) in dh.iter_mut().zip(h) {
        if hv <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Backward of sigmoid given the post-activation values: `dh *= s(1-s)`.
pub fn sigmoid_backward(h: &[f32], dh: &mut [f32]) {
    debug_assert_eq!(h.len(), dh.len());
    for (d, &s) in dh.iter_mut().zip(h) {
        let s = s as f64;
        *d = (*d as f64 * s * (1.0 - s)) as f32;
    }
}

/// Weight gradient `dw = x^T @ dz` for `kk ∈ [k_lo, k_hi)` only:
/// `dw_band` is the `(k_hi - k_lo, n)` row-major band of the full
/// `(k, n)` gradient. `i`-blocked: `MB` batch rows stream past each
/// band accumulator row per sweep, amortizing the accumulator traffic;
/// per-element order stays `i`-ascending, and the band decomposition is
/// exact (each `dw` element lives in exactly one band), so any band
/// split is bitwise equal to [`oracle::matmul_dw`].
pub fn matmul_dw_band(
    x: &[f32],
    dz: &[f32],
    m: usize,
    k: usize,
    n: usize,
    k_lo: usize,
    k_hi: usize,
    dw_band: &mut [f32],
) {
    debug_assert!(k_lo <= k_hi && k_hi <= k);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(dw_band.len(), (k_hi - k_lo) * n);
    let mut acc = vec![0.0f64; (k_hi - k_lo) * n];
    let mut i0 = 0;
    while i0 < m {
        let rb = MB.min(m - i0);
        for kk in k_lo..k_hi {
            let arow = &mut acc[(kk - k_lo) * n..(kk - k_lo + 1) * n];
            for r in 0..rb {
                let xv = x[(i0 + r) * k + kk] as f64;
                let dzrow = &dz[(i0 + r) * n..(i0 + r + 1) * n];
                for (a, &dv) in arow.iter_mut().zip(dzrow) {
                    *a += xv * dv as f64;
                }
            }
        }
        i0 += rb;
    }
    for (o, &a) in dw_band.iter_mut().zip(&acc) {
        *o = a as f32;
    }
}

/// Weight gradient `dw = x^T @ dz`: `x` is `(m, k)`, `dz` is `(m, n)`,
/// `dw` out is `(k, n)` row-major.
pub fn matmul_dw(x: &[f32], dz: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    matmul_dw_band(x, dz, m, k, n, 0, k, dw);
}

/// Weight gradient sharded by `kk`-**bands** over the pool: each job
/// owns a disjoint row band of `dw` (no partial sums are ever combined),
/// so the result is bitwise-identical to [`matmul_dw`] at any thread
/// count.
pub fn matmul_dw_ctx(
    ctx: &ParallelCtx,
    x: &[f32],
    dz: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(dw.len(), k * n);
    let bands = row_bands(k, ctx.threads(), m * k * n);
    if bands.len() <= 1 {
        matmul_dw(x, dz, m, k, n, dw);
        return;
    }
    let width = bands[0].1 - bands[0].0;
    let jobs: Vec<Job<'_>> = dw
        .chunks_mut(width * n)
        .zip(&bands)
        .map(|(oc, &(a, b))| Box::new(move || matmul_dw_band(x, dz, m, k, n, a, b, oc)) as Job<'_>)
        .collect();
    ctx.run(jobs);
}

/// Input gradient `dx = dz @ w^T`: `dz` is `(m, n)`, `w` is `(k, n)`,
/// `dx` out is `(m, k)`. Register-tiled: four `w` rows share one sweep
/// of the `dz` row (four independent f64 dot products per pass); the
/// per-element order is a plain `j`-ascending dot, bitwise equal to
/// [`oracle::matmul_dx`].
pub fn matmul_dx(dz: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    for i in 0..m {
        let dzrow = &dz[i * n..(i + 1) * n];
        let mut kk0 = 0;
        while kk0 + MB <= k {
            let w0 = &w[kk0 * n..(kk0 + 1) * n];
            let w1 = &w[(kk0 + 1) * n..(kk0 + 2) * n];
            let w2 = &w[(kk0 + 2) * n..(kk0 + 3) * n];
            let w3 = &w[(kk0 + 3) * n..(kk0 + 4) * n];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for j in 0..n {
                let dv = dzrow[j] as f64;
                a0 += dv * w0[j] as f64;
                a1 += dv * w1[j] as f64;
                a2 += dv * w2[j] as f64;
                a3 += dv * w3[j] as f64;
            }
            dx[i * k + kk0] = a0 as f32;
            dx[i * k + kk0 + 1] = a1 as f32;
            dx[i * k + kk0 + 2] = a2 as f32;
            dx[i * k + kk0 + 3] = a3 as f32;
            kk0 += MB;
        }
        for kk in kk0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f64;
            for (&dv, &wv) in dzrow.iter().zip(wrow) {
                acc += dv as f64 * wv as f64;
            }
            dx[i * k + kk] = acc as f32;
        }
    }
}

/// Input gradient sharded by output **rows** over the pool (disjoint
/// `dx` bands, no combine) — bitwise-identical to [`matmul_dx`] at any
/// thread count.
pub fn matmul_dx_ctx(
    ctx: &ParallelCtx,
    dz: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(dx.len(), m * k);
    let bands = row_bands(m, ctx.threads(), m * k * n);
    if bands.len() <= 1 {
        matmul_dx(dz, w, m, k, n, dx);
        return;
    }
    let width = bands[0].1 - bands[0].0;
    let jobs: Vec<Job<'_>> = dx
        .chunks_mut(width * k)
        .zip(&bands)
        .map(|(oc, &(a, b))| {
            let dzs = &dz[a * n..b * n];
            Box::new(move || matmul_dx(dzs, w, b - a, k, n, oc)) as Job<'_>
        })
        .collect();
    ctx.run(jobs);
}

/// Deterministic row-band plan for the `_ctx` kernels: uniform-width
/// bands (last one ragged) over `[0, rows)`, one per pool lane, or a
/// single band when parallel dispatch cannot pay for itself. Unlike
/// `plan_shards` this plan MAY depend on the thread count — the kernels
/// sharded with it write disjoint output bands with a fixed per-element
/// order, so the band boundaries never reach the arithmetic.
fn row_bands(rows: usize, threads: usize, products: usize) -> Vec<(usize, usize)> {
    if threads <= 1 || rows < 2 || products < PAR_MIN_PRODUCTS {
        return vec![(0, rows)];
    }
    let shards = threads.min(rows);
    let width = rows.div_ceil(shards);
    let mut bands = Vec::with_capacity(shards);
    let mut lo = 0;
    while lo < rows {
        let hi = (lo + width).min(rows);
        bands.push((lo, hi));
        lo = hi;
    }
    bands
}

/// Bias gradient `db = sum_rows(dz)` with f64 column accumulators.
pub fn bias_db(dz: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(db.len(), n);
    let mut acc = vec![0.0f64; n];
    for i in 0..m {
        for (a, &dv) in acc.iter_mut().zip(&dz[i * n..(i + 1) * n]) {
            *a += dv as f64;
        }
    }
    for (o, &a) in db.iter_mut().zip(&acc) {
        *o = a as f32;
    }
}

/// Embedding lookup + dense concat: the dlrm-style input layer.
///
/// `table` holds `fields` stacked `(vocab, dim)` tables (row of id `id`
/// in field `f` is table row `f·vocab + id`); `cat` is `(m, fields)`
/// i32 ids, `dense` is `(m, dense_dim)`. Writes
/// `out[i, :] = concat(table[f·vocab + cat[i,f], :] for f) ++ dense[i, :]`
/// with row stride `fields·dim + dense_dim`.
pub fn embedding_forward(
    table: &[f32],
    cat: &[i32],
    dense: &[f32],
    m: usize,
    fields: usize,
    vocab: usize,
    dim: usize,
    dense_dim: usize,
    out: &mut [f32],
) {
    let stride = fields * dim + dense_dim;
    debug_assert_eq!(table.len(), fields * vocab * dim);
    debug_assert_eq!(cat.len(), m * fields);
    debug_assert_eq!(dense.len(), m * dense_dim);
    debug_assert_eq!(out.len(), m * stride);
    for i in 0..m {
        let orow = &mut out[i * stride..(i + 1) * stride];
        for f in 0..fields {
            let id = cat[i * fields + f];
            // Hard assert (not debug): an out-of-range id would read the
            // wrong field's table (or out of bounds) silently in release.
            assert!(
                0 <= id && (id as usize) < vocab,
                "embedding id {id} out of range (field {f}, vocab {vocab})"
            );
            let trow = &table[(f * vocab + id as usize) * dim..][..dim];
            orow[f * dim..(f + 1) * dim].copy_from_slice(trow);
        }
        orow[fields * dim..].copy_from_slice(&dense[i * dense_dim..(i + 1) * dense_dim]);
    }
}

/// Embedding backward: scatter-add of the input-layer gradient into the
/// table gradient. Accumulates the whole table in f64 and visits rows in
/// ascending `(i, f)` order, so repeated ids sum in a fixed order —
/// deterministic at any call site. The dense tail of `dx0` is input
/// data's gradient and is dropped.
pub fn embedding_backward(
    dx0: &[f32],
    cat: &[i32],
    m: usize,
    fields: usize,
    vocab: usize,
    dim: usize,
    dense_dim: usize,
    dtable: &mut [f32],
) {
    let stride = fields * dim + dense_dim;
    debug_assert_eq!(dx0.len(), m * stride);
    debug_assert_eq!(cat.len(), m * fields);
    debug_assert_eq!(dtable.len(), fields * vocab * dim);
    let mut acc = vec![0.0f64; fields * vocab * dim];
    for i in 0..m {
        let drow = &dx0[i * stride..(i + 1) * stride];
        for f in 0..fields {
            let id = cat[i * fields + f];
            assert!(
                0 <= id && (id as usize) < vocab,
                "embedding id {id} out of range (field {f}, vocab {vocab})"
            );
            let arow = &mut acc[(f * vocab + id as usize) * dim..][..dim];
            for (a, &dv) in arow.iter_mut().zip(&drow[f * dim..(f + 1) * dim]) {
                *a += dv as f64;
            }
        }
    }
    for (o, &a) in dtable.iter_mut().zip(&acc) {
        *o = a as f32;
    }
}

/// LayerNorm forward over `(m, n)` rows, in place:
/// `h[i,:] = γ ⊙ (h[i,:] - μ_i)/√(σ²_i + ε) + β` with per-row mean and
/// (biased) variance computed in f64, `j`-ascending. Caches the
/// normalized activations `xhat` (f32, `(m, n)`) and per-row inverse
/// stddev `rstd` (f64, `m`) for the backward pass.
pub fn layernorm_forward(
    h: &mut [f32],
    m: usize,
    n: usize,
    gamma: &[f32],
    beta: &[f32],
    xhat: &mut [f32],
    rstd: &mut [f64],
) {
    debug_assert_eq!(h.len(), m * n);
    debug_assert_eq!(gamma.len(), n);
    debug_assert_eq!(beta.len(), n);
    debug_assert_eq!(xhat.len(), m * n);
    debug_assert_eq!(rstd.len(), m);
    let inv_n = 1.0 / n as f64;
    for i in 0..m {
        let hrow = &mut h[i * n..(i + 1) * n];
        let mut mean = 0.0f64;
        for &v in hrow.iter() {
            mean += v as f64;
        }
        mean *= inv_n;
        let mut var = 0.0f64;
        for &v in hrow.iter() {
            let d = v as f64 - mean;
            var += d * d;
        }
        var *= inv_n;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[i] = rs;
        let xrow = &mut xhat[i * n..(i + 1) * n];
        for j in 0..n {
            let xh = (hrow[j] as f64 - mean) * rs;
            xrow[j] = xh as f32;
            hrow[j] = (xh * gamma[j] as f64 + beta[j] as f64) as f32;
        }
    }
}

/// LayerNorm backward. Consumes the upstream gradient `dh` (w.r.t. the
/// affine LN output) in place, leaving the gradient w.r.t. the LN input;
/// writes `dgamma[j] = Σ_i dh[i,j]·xhat[i,j]` and `dbeta[j] = Σ_i
/// dh[i,j]` (f64 column accumulators, `i`-ascending). Per row, with
/// `dxhat = dh ⊙ γ`:
/// `dz[j] = rstd · (dxhat[j] - Σ_j dxhat / n - xhat[j] · Σ_j dxhat·xhat / n)`,
/// all row sums f64 `j`-ascending.
pub fn layernorm_backward(
    dh: &mut [f32],
    m: usize,
    n: usize,
    gamma: &[f32],
    xhat: &[f32],
    rstd: &[f64],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    debug_assert_eq!(dh.len(), m * n);
    debug_assert_eq!(gamma.len(), n);
    debug_assert_eq!(xhat.len(), m * n);
    debug_assert_eq!(rstd.len(), m);
    debug_assert_eq!(dgamma.len(), n);
    debug_assert_eq!(dbeta.len(), n);
    let mut gacc = vec![0.0f64; n];
    let mut bacc = vec![0.0f64; n];
    for i in 0..m {
        let drow = &dh[i * n..(i + 1) * n];
        let xrow = &xhat[i * n..(i + 1) * n];
        for j in 0..n {
            gacc[j] += drow[j] as f64 * xrow[j] as f64;
            bacc[j] += drow[j] as f64;
        }
    }
    for (o, &a) in dgamma.iter_mut().zip(&gacc) {
        *o = a as f32;
    }
    for (o, &a) in dbeta.iter_mut().zip(&bacc) {
        *o = a as f32;
    }
    let inv_n = 1.0 / n as f64;
    for i in 0..m {
        let drow = &mut dh[i * n..(i + 1) * n];
        let xrow = &xhat[i * n..(i + 1) * n];
        let mut s1 = 0.0f64;
        let mut s2 = 0.0f64;
        for j in 0..n {
            let dxh = drow[j] as f64 * gamma[j] as f64;
            s1 += dxh;
            s2 += dxh * xrow[j] as f64;
        }
        let rs = rstd[i];
        for j in 0..n {
            let dxh = drow[j] as f64 * gamma[j] as f64;
            drow[j] = (rs * (dxh - s1 * inv_n - xrow[j] as f64 * s2 * inv_n)) as f32;
        }
    }
}

/// Mean-square loss `mean_b 0.5*||y_b||^2` over `(m, n)` outputs.
/// Returns the f64 loss and writes `dy = y / m`.
pub fn mean_square_loss(y: &[f32], m: usize, n: usize, dy: &mut [f32]) -> f64 {
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(dy.len(), m * n);
    let inv_m = 1.0 / m as f64;
    let mut acc = 0.0f64;
    for (&v, d) in y.iter().zip(dy.iter_mut()) {
        let v = v as f64;
        acc += v * v;
        *d = (v * inv_m) as f32;
    }
    0.5 * acc * inv_m
}

/// Mean softmax cross-entropy over `(m, c)` logits with i32 labels.
/// Per-row log-sum-exp runs in f64 (max-shifted, so large logits cannot
/// overflow). Returns the f64 loss and writes
/// `dlogits = (softmax - onehot(y)) / m`.
pub fn softmax_xent_loss(logits: &[f32], y: &[i32], m: usize, c: usize, dl: &mut [f32]) -> f64 {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(y.len(), m);
    debug_assert_eq!(dl.len(), m * c);
    let inv_m = 1.0 / m as f64;
    let mut loss = 0.0f64;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let label = y[i] as usize;
        debug_assert!(label < c, "label {label} out of range (classes {c})");
        let mx = row.iter().fold(f64::NEG_INFINITY, |a, &v| a.max(v as f64));
        let mut z = 0.0f64;
        for &v in row {
            z += (v as f64 - mx).exp();
        }
        let lse = mx + z.ln();
        loss += lse - row[label] as f64;
        let drow = &mut dl[i * c..(i + 1) * c];
        for (j, (d, &v)) in drow.iter_mut().zip(row).enumerate() {
            let p = (v as f64 - mx).exp() / z;
            let target = if j == label { 1.0 } else { 0.0 };
            *d = ((p - target) * inv_m) as f32;
        }
    }
    loss * inv_m
}

/// Mean sigmoid binary-cross-entropy over `(m, 1)` logits with f32 {0,1}
/// labels — the CTR/detection-head loss (`data::ctr` emits f32 click
/// labels). Per element, in f64:
/// `max(z,0) - z·y + ln(1 + e^{-|z|})` (the overflow-free softplus form
/// of `-y·ln σ(z) - (1-y)·ln(1-σ(z))`). Returns the f64 loss and writes
/// `dz = (σ(z) - y) / m`.
pub fn sigmoid_bce_loss(logits: &[f32], y: &[f32], m: usize, dl: &mut [f32]) -> f64 {
    debug_assert_eq!(logits.len(), m);
    debug_assert_eq!(y.len(), m);
    debug_assert_eq!(dl.len(), m);
    let inv_m = 1.0 / m as f64;
    let mut loss = 0.0f64;
    for i in 0..m {
        let z = logits[i] as f64;
        // Hard assert (not debug): an out-of-range label would silently
        // corrupt loss and gradients in release builds (unlike
        // softmax_xent, whose bad label panics on the row index).
        assert!(
            y[i] == 0.0 || y[i] == 1.0,
            "BCE label must be exactly 0/1, got {}",
            y[i]
        );
        let t = y[i] as f64;
        loss += z.max(0.0) - z * t + (-z.abs()).exp().ln_1p();
        let s = 1.0 / (1.0 + (-z).exp());
        dl[i] = ((s - t) * inv_m) as f32;
    }
    loss * inv_m
}

/// Per-row argmax == label indicator (the `correct` eval output of the
/// classifier artifacts; ties resolve to the lowest index, like argmax).
pub fn argmax_correct(logits: &[f32], y: &[i32], m: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(y.len(), m);
    debug_assert_eq!(out.len(), m);
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        out[i] = if best as i32 == y[i] { 1.0 } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ParallelPolicy;
    use crate::util::prng::Rng;

    #[test]
    fn matmul_small_exact() {
        // (2,3) @ (3,2)
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut out = [0.0f32; 4];
        matmul(&x, 2, 3, &w, 2, &mut out);
        assert_eq!(out, [4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn blocked_kernels_match_oracle_on_ragged_shapes() {
        // Quick in-crate check; the thorough ragged/threaded property
        // suite lives in tests/interp_kernel_equiv.rs.
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (9, 66, 130)] {
            let mut x = vec![0.0f32; m * k];
            let mut w = vec![0.0f32; k * n];
            let mut dz = vec![0.0f32; m * n];
            rng.fill_normal_f32(&mut x, 1.0);
            rng.fill_normal_f32(&mut w, 1.0);
            rng.fill_normal_f32(&mut dz, 1.0);
            let (mut a, mut b) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
            matmul(&x, m, k, &w, n, &mut a);
            oracle::matmul(&x, m, k, &w, n, &mut b);
            assert_eq!(a, b, "matmul ({m},{k},{n})");
            let (mut a, mut b) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
            matmul_dw(&x, &dz, m, k, n, &mut a);
            oracle::matmul_dw(&x, &dz, m, k, n, &mut b);
            assert_eq!(a, b, "matmul_dw ({m},{k},{n})");
            let (mut a, mut b) = (vec![0.0f32; m * k], vec![0.0f32; m * k]);
            matmul_dx(&dz, &w, m, k, n, &mut a);
            oracle::matmul_dx(&dz, &w, m, k, n, &mut b);
            assert_eq!(a, b, "matmul_dx ({m},{k},{n})");
        }
    }

    #[test]
    fn nan_and_inf_weights_poison_zero_inputs() {
        // Regression: the old kernels skipped xv == 0.0 terms, silently
        // turning 0 × inf / 0 × NaN into 0 and masking poisoned params.
        let x = [0.0f32, 1.0];
        let w = [f32::NAN, 2.0]; // (2, 1)
        let mut out = [0.0f32; 1];
        matmul(&x, 1, 2, &w, 1, &mut out);
        assert!(out[0].is_nan(), "0 × NaN weight must propagate NaN");
        let w = [f32::INFINITY, 2.0];
        matmul(&x, 1, 2, &w, 1, &mut out);
        assert!(out[0].is_nan(), "0 × inf weight must propagate NaN");
        // Same for the weight gradient: zero input column × NaN dz.
        let x = [0.0f32];
        let dz = [f32::NAN];
        let mut dw = [0.0f32; 1];
        matmul_dw(&x, &dz, 1, 1, 1, &mut dw);
        assert!(dw[0].is_nan(), "0 × NaN dz must propagate NaN into dw");
    }

    #[test]
    fn ctx_kernels_match_serial_bitwise() {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 3,
            min_shard_elems: 16,
        });
        let (m, k, n) = (13usize, 47usize, 129usize);
        let mut rng = Rng::new(11);
        let mut x = vec![0.0f32; m * k];
        let mut w = vec![0.0f32; k * n];
        let mut dz = vec![0.0f32; m * n];
        rng.fill_normal_f32(&mut x, 1.0);
        rng.fill_normal_f32(&mut w, 1.0);
        rng.fill_normal_f32(&mut dz, 1.0);
        let (mut a, mut b) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        matmul_ctx(&ctx, &x, m, k, &w, n, &mut a);
        matmul(&x, m, k, &w, n, &mut b);
        assert_eq!(a, b);
        let (mut a, mut b) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
        matmul_dw_ctx(&ctx, &x, &dz, m, k, n, &mut a);
        matmul_dw(&x, &dz, m, k, n, &mut b);
        assert_eq!(a, b);
        let (mut a, mut b) = (vec![0.0f32; m * k], vec![0.0f32; m * k]);
        matmul_dx_ctx(&ctx, &dz, &w, m, k, n, &mut a);
        matmul_dx(&dz, &w, m, k, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn row_bands_cover_and_respect_thresholds() {
        assert_eq!(row_bands(10, 1, usize::MAX), vec![(0, 10)]);
        assert_eq!(row_bands(10, 4, 0), vec![(0, 10)]); // tiny work
        let bands = row_bands(10, 4, usize::MAX);
        assert_eq!(bands, vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
        let bands = row_bands(3, 8, usize::MAX);
        assert_eq!(bands.len(), 3); // never more bands than rows
    }

    #[test]
    fn bias_relu_sigmoid_roundtrip() {
        let mut h = [-1.0f32, 0.5, -0.25, 2.0];
        bias_add(&mut h, 2, 2, &[0.25, -0.5]);
        assert_eq!(h, [-0.75, 0.0, 0.0, 1.5]);
        let mut r = h;
        relu(&mut r);
        assert_eq!(r, [0.0, 0.0, 0.0, 1.5]);
        let mut s = [0.0f32];
        sigmoid(&mut s);
        assert!((s[0] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn embedding_forward_gathers_and_concats() {
        // 2 fields, vocab 3, dim 2, dense 1; table rows are recognizable.
        let table: Vec<f32> = (0..2 * 3 * 2).map(|v| v as f32).collect();
        let cat = [2i32, 0, 1, 1]; // (2 rows, 2 fields)
        let dense = [10.0f32, 20.0];
        let mut out = [0.0f32; 2 * 5];
        embedding_forward(&table, &cat, &dense, 2, 2, 3, 2, 1, &mut out);
        // row 0: field0 id2 -> table row 2 = [4,5]; field1 id0 -> row 3 = [6,7]
        assert_eq!(&out[..5], &[4.0, 5.0, 6.0, 7.0, 10.0]);
        // row 1: field0 id1 -> row 1 = [2,3]; field1 id1 -> row 4 = [8,9]
        assert_eq!(&out[5..], &[2.0, 3.0, 8.0, 9.0, 20.0]);
    }

    #[test]
    fn embedding_backward_scatter_adds_repeated_ids() {
        // Both rows hit field-0 id 1: gradients must sum.
        let cat = [1i32, 1];
        let dx0 = [1.0f32, 2.0, 0.5, 10.0, 20.0, 0.25]; // stride 3 = 1 field * dim 2 + dense 1
        let mut dt = [0.0f32; 2 * 2]; // 1 field, vocab 2, dim 2
        embedding_backward(&dx0, &cat, 2, 1, 2, 2, 1, &mut dt);
        assert_eq!(dt, [0.0, 0.0, 11.0, 22.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn embedding_rejects_out_of_range_id() {
        let table = [0.0f32; 4];
        let cat = [7i32];
        let dense = [0.0f32];
        let mut out = [0.0f32; 3];
        embedding_forward(&table, &cat, &dense, 1, 1, 2, 2, 1, &mut out);
    }

    #[test]
    fn layernorm_forward_normalizes_rows() {
        let mut h = [1.0f32, 2.0, 3.0, 4.0, -10.0, 0.0, 10.0, 20.0];
        let gamma = [1.0f32; 4];
        let beta = [0.0f32; 4];
        let mut xhat = [0.0f32; 8];
        let mut rstd = [0.0f64; 2];
        layernorm_forward(&mut h, 2, 4, &gamma, &beta, &mut xhat, &mut rstd);
        for i in 0..2 {
            let row = &h[i * 4..(i + 1) * 4];
            let mean: f64 = row.iter().map(|&v| v as f64).sum::<f64>() / 4.0;
            let var: f64 = row.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / 4.0;
            assert!(mean.abs() < 1e-6, "row {i} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {i} var {var}");
        }
        // With identity affine, output == xhat.
        assert_eq!(h, xhat);
    }

    #[test]
    fn layernorm_backward_gradient_sums_are_consistent() {
        // dz rows must be orthogonal to (1, xhat): the LN output is
        // invariant to input shifts and scalings, so those directions
        // carry no gradient.
        let mut h = [0.5f32, -1.0, 2.0, 0.25, 3.0, -0.5];
        let gamma = [1.5f32, 0.5, 2.0];
        let beta = [0.1f32, -0.2, 0.3];
        let mut xhat = [0.0f32; 6];
        let mut rstd = [0.0f64; 2];
        layernorm_forward(&mut h, 2, 3, &gamma, &beta, &mut xhat, &mut rstd);
        let mut dh = [1.0f32, -2.0, 0.5, 0.75, 0.25, -1.5];
        let dh0 = dh;
        let mut dgamma = [0.0f32; 3];
        let mut dbeta = [0.0f32; 3];
        layernorm_backward(&mut dh, 2, 3, &gamma, &xhat, &rstd, &mut dgamma, &mut dbeta);
        for i in 0..2 {
            let dz = &dh[i * 3..(i + 1) * 3];
            let xr = &xhat[i * 3..(i + 1) * 3];
            let s: f64 = dz.iter().map(|&v| v as f64).sum();
            let sx: f64 = dz.iter().zip(xr).map(|(&d, &x)| d as f64 * x as f64).sum();
            assert!(s.abs() < 1e-5, "row {i} shift leak {s}");
            assert!(sx.abs() < 1e-5, "row {i} scale leak {sx}");
        }
        // dbeta is the plain column sum of the upstream grad.
        for j in 0..3 {
            let want = dh0[j] as f64 + dh0[3 + j] as f64;
            assert!((dbeta[j] as f64 - want).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let logits = [0.0f32; 6]; // (2, 3) uniform
        let y = [0i32, 2];
        let mut dl = [0.0f32; 6];
        let loss = softmax_xent_loss(&logits, &y, 2, 3, &mut dl);
        assert!((loss - (3.0f64).ln()).abs() < 1e-12);
        // Gradient rows sum to zero and the label entry is negative.
        assert!((dl[0] - (1.0 / 3.0 - 1.0) as f32 / 2.0).abs() < 1e-6);
        let row_sum: f32 = dl[..3].iter().sum();
        assert!(row_sum.abs() < 1e-6);
    }

    #[test]
    fn mean_square_matches_hand_value() {
        let y = [1.0f32, -2.0, 3.0, 0.0]; // (2, 2)
        let mut dy = [0.0f32; 4];
        let loss = mean_square_loss(&y, 2, 2, &mut dy);
        assert!((loss - 0.5 * (1.0 + 4.0 + 9.0) / 2.0).abs() < 1e-12);
        assert_eq!(dy, [0.5, -1.0, 1.5, 0.0]);
    }

    #[test]
    fn sigmoid_bce_hand_values_and_stability() {
        // z = 0: loss = ln 2 per element regardless of label; dz = ±0.5/m.
        let logits = [0.0f32, 0.0];
        let mut dl = [0.0f32; 2];
        let loss = sigmoid_bce_loss(&logits, &[1.0, 0.0], 2, &mut dl);
        assert!((loss - (2.0f64).ln()).abs() < 1e-12);
        assert!((dl[0] + 0.25).abs() < 1e-7);
        assert!((dl[1] - 0.25).abs() < 1e-7);
        // Confident-correct: near-zero loss; confident-wrong: ~|z|.
        let logits = [30.0f32, -30.0];
        let loss = sigmoid_bce_loss(&logits, &[1.0, 0.0], 2, &mut dl);
        assert!(loss < 1e-10, "{loss}");
        let loss = sigmoid_bce_loss(&logits, &[0.0, 1.0], 2, &mut dl);
        assert!((loss - 30.0).abs() < 1e-6, "{loss}");
        // Huge logits stay finite (softplus form cannot overflow).
        let logits = [500.0f32, -500.0];
        let loss = sigmoid_bce_loss(&logits, &[0.0, 1.0], 2, &mut dl);
        assert!(loss.is_finite() && dl.iter().all(|d| d.is_finite()));
    }

    #[test]
    #[should_panic(expected = "exactly 0/1")]
    fn sigmoid_bce_rejects_soft_labels() {
        let mut dl = [0.0f32; 1];
        sigmoid_bce_loss(&[0.0], &[0.5], 1, &mut dl);
    }

    #[test]
    fn argmax_correct_handles_ties_low() {
        let logits = [1.0f32, 1.0, 0.0, 0.5, 2.0, 0.5]; // (2, 3)
        let mut out = [9.0f32; 2];
        argmax_correct(&logits, &[0, 1], 2, 3, &mut out);
        assert_eq!(out, [1.0, 1.0]);
        argmax_correct(&logits, &[1, 0], 2, 3, &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }

    #[test]
    fn argmax_correct_out_of_range_label_is_never_correct() {
        let logits = [1.0f32, 0.0, 0.0]; // (1, 3), argmax = 0
        let mut out = [9.0f32; 1];
        argmax_correct(&logits, &[7], 1, 3, &mut out);
        assert_eq!(out, [0.0]);
        argmax_correct(&logits, &[-1], 1, 3, &mut out);
        assert_eq!(out, [0.0]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn argmax_correct_rejects_mismatched_label_count() {
        let logits = [0.0f32; 6];
        let mut out = [0.0f32; 2];
        argmax_correct(&logits, &[0], 2, 3, &mut out);
    }
}
