//! Straight-line f64 reference implementation — the second, independent
//! pair of eyes behind the builtin golden checksums.
//!
//! When artifacts are built by `python/compile/aot.py`, goldens come from
//! jax itself. The builtin fallback specs have no Python to lean on, so
//! their goldens are minted here: textbook f64 loops (no shared kernels,
//! different loop structure from [`super::ops`]) over the same init
//! vector and deterministic golden batch. `tests/runtime_golden.rs` then
//! cross-checks the f32 interpreter against these values, which catches a
//! formula error in either implementation.

use crate::data::Batch;
use crate::runtime::artifact::{ArtifactSpec, Golden};
use crate::util::error::{bail, Context, Result};

use super::program::{Act, Loss, ProgramSpec};

/// Forward + backward in pure f64. Returns `(loss, flat_grads)`.
pub fn loss_and_grad(
    prog: &ProgramSpec,
    params: &[f32],
    batch: &Batch,
) -> Result<(f64, Vec<f64>)> {
    let x32 = batch[0].as_f32().context("reference: input 0 must be f32")?;
    let x: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
    let m = x.len() / prog.in_dim();
    let p: Vec<f64> = params.iter().map(|&v| v as f64).collect();

    // Forward: keep every post-activation.
    let mut acts: Vec<Vec<f64>> = Vec::new();
    for (li, l) in prog.layers.iter().enumerate() {
        let input: &[f64] = if li == 0 { &x } else { &acts[li - 1] };
        let (k, n) = (l.in_dim, l.out_dim);
        let mut h = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = match l.b_off {
                    Some(b) => p[b + j],
                    None => 0.0,
                };
                for kk in 0..k {
                    acc += input[i * k + kk] * p[l.w_off + kk * n + j];
                }
                h[i * n + j] = match l.act {
                    Act::Linear => acc,
                    Act::Relu => acc.max(0.0),
                    Act::Sigmoid => 1.0 / (1.0 + (-acc).exp()),
                };
            }
        }
        acts.push(h);
    }

    // Loss + dLoss/d(final output).
    let out = acts.last().context("reference: empty program")?;
    let c = prog.out_dim();
    let mut loss = 0.0f64;
    let mut dh = vec![0.0f64; out.len()];
    match prog.loss {
        Loss::MeanSquare => {
            for (i, &v) in out.iter().enumerate() {
                loss += 0.5 * v * v;
                dh[i] = v;
            }
            loss /= m as f64;
            dh.iter_mut().for_each(|d| *d /= m as f64);
        }
        Loss::SoftmaxXent { classes } => {
            let y = batch[1].as_i32().context("reference: input 1 must be i32")?;
            if classes != c {
                bail!("reference: classes {classes} != out dim {c}");
            }
            for i in 0..m {
                let row = &out[i * c..(i + 1) * c];
                let label = y[i] as usize;
                let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let z: f64 = row.iter().map(|&v| (v - mx).exp()).sum();
                loss += mx + z.ln() - row[label];
                for j in 0..c {
                    let p_j = (row[j] - mx).exp() / z;
                    dh[i * c + j] = (p_j - if j == label { 1.0 } else { 0.0 }) / m as f64;
                }
            }
            loss /= m as f64;
        }
        Loss::SigmoidBce => {
            let y = batch[1].as_i32().context("reference: input 1 must be i32")?;
            if c != 1 {
                bail!("reference: sigmoid_bce needs out dim 1, got {c}");
            }
            for i in 0..m {
                let z = out[i];
                let t = y[i] as f64;
                if y[i] != 0 && y[i] != 1 {
                    bail!("reference: BCE label must be 0/1, got {}", y[i]);
                }
                loss += z.max(0.0) - z * t + (-z.abs()).exp().ln_1p();
                dh[i] = (1.0 / (1.0 + (-z).exp()) - t) / m as f64;
            }
            loss /= m as f64;
        }
    }

    // Backward, last layer to first.
    let mut grads = vec![0.0f64; prog.param_dim()];
    for li in (0..prog.layers.len()).rev() {
        let l = &prog.layers[li];
        let (k, n) = (l.in_dim, l.out_dim);
        let h = &acts[li];
        // Activation derivative through the stored post-activations.
        let mut dz = dh.clone();
        for (d, &hv) in dz.iter_mut().zip(h.iter()) {
            match l.act {
                Act::Linear => {}
                Act::Relu => {
                    if hv <= 0.0 {
                        *d = 0.0;
                    }
                }
                Act::Sigmoid => *d *= hv * (1.0 - hv),
            }
        }
        let input: &[f64] = if li == 0 { &x } else { &acts[li - 1] };
        for kk in 0..k {
            for j in 0..n {
                let mut acc = 0.0f64;
                for i in 0..m {
                    acc += input[i * k + kk] * dz[i * n + j];
                }
                grads[l.w_off + kk * n + j] = acc;
            }
        }
        if let Some(b_off) = l.b_off {
            for j in 0..n {
                let mut acc = 0.0f64;
                for i in 0..m {
                    acc += dz[i * n + j];
                }
                grads[b_off + j] = acc;
            }
        }
        if li > 0 {
            let mut dx = vec![0.0f64; m * k];
            for i in 0..m {
                for kk in 0..k {
                    let mut acc = 0.0f64;
                    for j in 0..n {
                        acc += dz[i * n + j] * p[l.w_off + kk * n + j];
                    }
                    dx[i * k + kk] = acc;
                }
            }
            dh = dx;
        }
    }
    Ok((loss, grads))
}

/// Mint the golden checksums for a builtin train artifact: seed-0 init,
/// deterministic golden batch, all-f64 math.
pub fn golden(spec: &ArtifactSpec) -> Result<Golden> {
    let prog = spec
        .program
        .as_ref()
        .with_context(|| format!("{}: no program to mint a golden from", spec.name))?;
    let params = spec.load_init(0)?;
    let batch = super::golden_batch(spec);
    let (loss, grads) = loss_and_grad(prog, &params, &batch)?;
    let grad_sum: f64 = grads.iter().sum();
    let grad_l2: f64 = grads.iter().map(|g| g * g).sum::<f64>().sqrt();
    Ok(Golden {
        seed: 0,
        loss,
        grad_sum,
        grad_l2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Array;
    use crate::runtime::interp::program::Dense;
    use crate::util::prng::Rng;

    /// Tiny 2-layer relu net: reference vs interpreter must agree to
    /// ~f32 rounding (the interpreter stores f32 at layer boundaries).
    #[test]
    fn reference_matches_interpreter_on_small_net() {
        let prog = ProgramSpec {
            layers: vec![
                Dense {
                    in_dim: 5,
                    out_dim: 4,
                    w_off: 4,
                    b_off: Some(0),
                    act: Act::Relu,
                    init_std: 0.5,
                },
                Dense {
                    in_dim: 4,
                    out_dim: 3,
                    w_off: 27,
                    b_off: Some(24),
                    act: Act::Linear,
                    init_std: 0.5,
                },
            ],
            loss: Loss::SoftmaxXent { classes: 3 },
        };
        prog.validate().unwrap();
        let params = super::super::init_params(&prog, 7);
        let m = 6usize;
        let mut rng = Rng::new(3);
        let mut x = vec![0.0f32; m * 5];
        rng.fill_normal_f32(&mut x, 1.0);
        let y: Vec<i32> = (0..m as i32).map(|i| i % 3).collect();
        let batch: Batch = vec![Array::F32(x, vec![m, 5]), Array::I32(y, vec![m])];

        let (ref_loss, ref_grads) = loss_and_grad(&prog, &params, &batch).unwrap();

        let spec_like_exec = super::super::InterpExec { prog: prog.clone() };
        let mut grads = vec![0.0f32; prog.param_dim()];
        let loss = spec_like_exec
            .run_train_stream(&params, &batch, &mut grads, &mut |_, _, _| {})
            .unwrap();

        assert!((loss as f64 - ref_loss).abs() < 1e-5 * ref_loss.abs().max(1.0));
        for (i, (&g, &r)) in grads.iter().zip(&ref_grads).enumerate() {
            assert!(
                (g as f64 - r).abs() < 1e-5 * r.abs().max(1e-3),
                "grad[{i}]: interp {g} vs reference {r}"
            );
        }
    }
}
