//! Straight-line f64 reference implementation — the second, independent
//! pair of eyes behind the builtin golden checksums.
//!
//! When artifacts are built by `python/compile/aot.py`, goldens come from
//! jax itself. The builtin fallback specs have no Python to lean on, so
//! their goldens are minted here: textbook f64 loops (no shared kernels,
//! different loop structure from [`super::ops`]) over the same init
//! vector and deterministic golden batch. `tests/runtime_golden.rs` then
//! cross-checks the f32 interpreter against these values, which catches a
//! formula error in either implementation.

use crate::data::Batch;
use crate::runtime::artifact::{ArtifactSpec, Golden};
use crate::util::error::{bail, Context, Result};

use super::ops::LN_EPS;
use super::program::{Act, Loss, ProgramSpec};

/// Forward + backward in pure f64. Returns `(loss, flat_grads)`.
pub fn loss_and_grad(
    prog: &ProgramSpec,
    params: &[f32],
    batch: &Batch,
) -> Result<(f64, Vec<f64>)> {
    let p: Vec<f64> = params.iter().map(|&v| v as f64).collect();

    // Assemble the first-layer input: either the raw f32 features or the
    // embedding gather ++ dense concat.
    let (x, m, cat, label_idx) = if let Some(e) = prog.embed.as_ref() {
        let cat = batch[0].as_i32().context("reference: input 0 must be i32 ids")?;
        let dense = batch[1].as_f32().context("reference: input 1 must be f32 dense")?;
        let m = cat.len() / e.fields;
        let stride = e.x_dim();
        let mut x = vec![0.0f64; m * stride];
        for i in 0..m {
            for f in 0..e.fields {
                let id = cat[i * e.fields + f];
                if id < 0 || id as usize >= e.vocab {
                    bail!("reference: embedding id {id} out of range");
                }
                let trow = e.t_off + (f * e.vocab + id as usize) * e.dim;
                for j in 0..e.dim {
                    x[i * stride + f * e.dim + j] = p[trow + j];
                }
            }
            for j in 0..e.dense_dim {
                x[i * stride + e.fields * e.dim + j] = dense[i * e.dense_dim + j] as f64;
            }
        }
        (x, m, Some(cat), 2usize)
    } else {
        let x32 = batch[0].as_f32().context("reference: input 0 must be f32")?;
        let x: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
        let m = x.len() / prog.in_dim();
        (x, m, None, 1usize)
    };

    // Forward: keep every post-activation plus the LN caches.
    let mut acts: Vec<Vec<f64>> = Vec::new();
    let mut xhats: Vec<Vec<f64>> = Vec::new();
    let mut rstds: Vec<Vec<f64>> = Vec::new();
    for (li, l) in prog.layers.iter().enumerate() {
        let input: &[f64] = if li == 0 { &x } else { &acts[li - 1] };
        let (k, n) = (l.in_dim, l.out_dim);
        let mut h = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = match l.b_off {
                    Some(b) => p[b + j],
                    None => 0.0,
                };
                for kk in 0..k {
                    acc += input[i * k + kk] * p[l.w_off + kk * n + j];
                }
                h[i * n + j] = acc;
            }
        }
        let (mut xhat, mut rstd) = (Vec::new(), Vec::new());
        if let Some(ln) = l.ln {
            xhat = vec![0.0f64; m * n];
            rstd = vec![0.0f64; m];
            for i in 0..m {
                let row = &mut h[i * n..(i + 1) * n];
                let mean: f64 = row.iter().sum::<f64>() / n as f64;
                let var: f64 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
                let rs = 1.0 / (var + LN_EPS).sqrt();
                rstd[i] = rs;
                for j in 0..n {
                    let xh = (row[j] - mean) * rs;
                    xhat[i * n + j] = xh;
                    row[j] = xh * p[ln.g_off + j] + p[ln.b_off + j];
                }
            }
        }
        for v in h.iter_mut() {
            *v = match l.act {
                Act::Linear => *v,
                Act::Relu => v.max(0.0),
                Act::Sigmoid => 1.0 / (1.0 + (-*v).exp()),
            };
        }
        acts.push(h);
        xhats.push(xhat);
        rstds.push(rstd);
    }

    // Loss + dLoss/d(final output).
    let out = acts.last().context("reference: empty program")?;
    let c = prog.out_dim();
    let mut loss = 0.0f64;
    let mut dh = vec![0.0f64; out.len()];
    match prog.loss {
        Loss::MeanSquare => {
            for (i, &v) in out.iter().enumerate() {
                loss += 0.5 * v * v;
                dh[i] = v;
            }
            loss /= m as f64;
            dh.iter_mut().for_each(|d| *d /= m as f64);
        }
        Loss::SoftmaxXent { classes } => {
            let y = batch[label_idx]
                .as_i32()
                .context("reference: labels must be i32")?;
            if classes != c {
                bail!("reference: classes {classes} != out dim {c}");
            }
            for i in 0..m {
                let row = &out[i * c..(i + 1) * c];
                let label = y[i] as usize;
                let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let z: f64 = row.iter().map(|&v| (v - mx).exp()).sum();
                loss += mx + z.ln() - row[label];
                for j in 0..c {
                    let p_j = (row[j] - mx).exp() / z;
                    dh[i * c + j] = (p_j - if j == label { 1.0 } else { 0.0 }) / m as f64;
                }
            }
            loss /= m as f64;
        }
        Loss::SigmoidBce => {
            // Labels arrive as f32 clicks (data::ctr) or i32 {0,1}.
            let y: Vec<f64> = match batch[label_idx].as_f32() {
                Some(v) => v.iter().map(|&t| t as f64).collect(),
                None => batch[label_idx]
                    .as_i32()
                    .context("reference: BCE labels must be f32 or i32")?
                    .iter()
                    .map(|&t| t as f64)
                    .collect(),
            };
            if c != 1 {
                bail!("reference: sigmoid_bce needs out dim 1, got {c}");
            }
            for i in 0..m {
                let z = out[i];
                let t = y[i];
                if t != 0.0 && t != 1.0 {
                    bail!("reference: BCE label must be 0/1, got {t}");
                }
                loss += z.max(0.0) - z * t + (-z.abs()).exp().ln_1p();
                dh[i] = (1.0 / (1.0 + (-z).exp()) - t) / m as f64;
            }
            loss /= m as f64;
        }
    }

    // Backward, last layer to first.
    let mut grads = vec![0.0f64; prog.param_dim()];
    for li in (0..prog.layers.len()).rev() {
        let l = &prog.layers[li];
        let (k, n) = (l.in_dim, l.out_dim);
        let h = &acts[li];
        // Activation derivative through the stored post-activations.
        let mut dz = dh.clone();
        for (d, &hv) in dz.iter_mut().zip(h.iter()) {
            match l.act {
                Act::Linear => {}
                Act::Relu => {
                    if hv <= 0.0 {
                        *d = 0.0;
                    }
                }
                Act::Sigmoid => *d *= hv * (1.0 - hv),
            }
        }
        if let Some(ln) = l.ln {
            // dz is d/d(LN affine output) here: accumulate gamma/beta
            // grads, then map dz back through the normalization.
            let xhat = &xhats[li];
            let rstd = &rstds[li];
            for j in 0..n {
                let mut dg = 0.0f64;
                let mut db = 0.0f64;
                for i in 0..m {
                    dg += dz[i * n + j] * xhat[i * n + j];
                    db += dz[i * n + j];
                }
                grads[ln.g_off + j] = dg;
                grads[ln.b_off + j] = db;
            }
            for i in 0..m {
                let mut s1 = 0.0f64;
                let mut s2 = 0.0f64;
                for j in 0..n {
                    let dxh = dz[i * n + j] * p[ln.g_off + j];
                    s1 += dxh;
                    s2 += dxh * xhat[i * n + j];
                }
                for j in 0..n {
                    let dxh = dz[i * n + j] * p[ln.g_off + j];
                    dz[i * n + j] =
                        rstd[i] * (dxh - s1 / n as f64 - xhat[i * n + j] * s2 / n as f64);
                }
            }
        }
        let input: &[f64] = if li == 0 { &x } else { &acts[li - 1] };
        for kk in 0..k {
            for j in 0..n {
                let mut acc = 0.0f64;
                for i in 0..m {
                    acc += input[i * k + kk] * dz[i * n + j];
                }
                grads[l.w_off + kk * n + j] = acc;
            }
        }
        if let Some(b_off) = l.b_off {
            for j in 0..n {
                let mut acc = 0.0f64;
                for i in 0..m {
                    acc += dz[i * n + j];
                }
                grads[b_off + j] = acc;
            }
        }
        if li > 0 || prog.embed.is_some() {
            let mut dx = vec![0.0f64; m * k];
            for i in 0..m {
                for kk in 0..k {
                    let mut acc = 0.0f64;
                    for j in 0..n {
                        acc += dz[i * n + j] * p[l.w_off + kk * n + j];
                    }
                    dx[i * k + kk] = acc;
                }
            }
            dh = dx;
        }
    }
    if let Some(e) = prog.embed.as_ref() {
        // Scatter-add the input gradient into the table rows; the dense
        // tail is input data's gradient and is dropped.
        let cat = cat.expect("embed path decoded ids above");
        let stride = e.x_dim();
        for i in 0..m {
            for f in 0..e.fields {
                let id = cat[i * e.fields + f] as usize;
                let trow = e.t_off + (f * e.vocab + id) * e.dim;
                for j in 0..e.dim {
                    grads[trow + j] += dh[i * stride + f * e.dim + j];
                }
            }
        }
    }
    Ok((loss, grads))
}

/// Mint the golden checksums for a builtin train artifact: seed-0 init,
/// deterministic golden batch, all-f64 math.
pub fn golden(spec: &ArtifactSpec) -> Result<Golden> {
    let prog = spec
        .program
        .as_ref()
        .with_context(|| format!("{}: no program to mint a golden from", spec.name))?;
    let params = spec.load_init(0)?;
    let batch = super::golden_batch(spec);
    let (loss, grads) = loss_and_grad(prog, &params, &batch)?;
    let grad_sum: f64 = grads.iter().sum();
    let grad_l2: f64 = grads.iter().map(|g| g * g).sum::<f64>().sqrt();
    Ok(Golden {
        seed: 0,
        loss,
        grad_sum,
        grad_l2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Array;
    use crate::runtime::interp::program::{Dense, Embedding, LayerNorm};
    use crate::util::prng::Rng;

    /// Tiny 2-layer relu net: reference vs interpreter must agree to
    /// ~f32 rounding (the interpreter stores f32 at layer boundaries).
    #[test]
    fn reference_matches_interpreter_on_small_net() {
        let prog = ProgramSpec {
            embed: None,
            layers: vec![
                Dense {
                    in_dim: 5,
                    out_dim: 4,
                    w_off: 4,
                    b_off: Some(0),
                    ln: None,
                    act: Act::Relu,
                    init_std: 0.5,
                },
                Dense {
                    in_dim: 4,
                    out_dim: 3,
                    w_off: 27,
                    b_off: Some(24),
                    ln: None,
                    act: Act::Linear,
                    init_std: 0.5,
                },
            ],
            loss: Loss::SoftmaxXent { classes: 3 },
        };
        prog.validate().unwrap();
        let params = super::super::init_params(&prog, 7);
        let m = 6usize;
        let mut rng = Rng::new(3);
        let mut x = vec![0.0f32; m * 5];
        rng.fill_normal_f32(&mut x, 1.0);
        let y: Vec<i32> = (0..m as i32).map(|i| i % 3).collect();
        let batch: Batch = vec![Array::F32(x, vec![m, 5]), Array::I32(y, vec![m])];

        let (ref_loss, ref_grads) = loss_and_grad(&prog, &params, &batch).unwrap();

        let spec_like_exec = super::super::InterpExec { prog: prog.clone() };
        let mut grads = vec![0.0f32; prog.param_dim()];
        let loss = spec_like_exec
            .run_train_stream(&params, &batch, &mut grads, &mut |_, _, _| {})
            .unwrap();

        assert!((loss as f64 - ref_loss).abs() < 1e-5 * ref_loss.abs().max(1.0));
        for (i, (&g, &r)) in grads.iter().zip(&ref_grads).enumerate() {
            assert!(
                (g as f64 - r).abs() < 1e-5 * r.abs().max(1e-3),
                "grad[{i}]: interp {g} vs reference {r}"
            );
        }
    }

    /// Embedding + layernorm path: reference vs interpreter on a tiny
    /// dlrm-shaped net (2 fields × vocab 3 × dim 2 + 1 dense → LN relu 3
    /// → 1 logit, BCE). Catches a formula error in either side's LN or
    /// scatter-add.
    #[test]
    fn reference_matches_interpreter_with_embed_and_ln() {
        // Layout: table 0..12, l0 b 12..15, ln beta 15..18,
        // ln gamma 18..21, l0 w 21..36, l1 b 36..37, l1 w 37..40.
        let prog = ProgramSpec {
            embed: Some(Embedding {
                fields: 2,
                vocab: 3,
                dim: 2,
                dense_dim: 1,
                t_off: 0,
                init_std: 0.4,
            }),
            layers: vec![
                Dense {
                    in_dim: 5,
                    out_dim: 3,
                    w_off: 21,
                    b_off: Some(12),
                    ln: Some(LayerNorm { g_off: 18, b_off: 15 }),
                    act: Act::Relu,
                    init_std: 0.5,
                },
                Dense {
                    in_dim: 3,
                    out_dim: 1,
                    w_off: 37,
                    b_off: Some(36),
                    ln: None,
                    act: Act::Linear,
                    init_std: 0.5,
                },
            ],
            loss: Loss::SigmoidBce,
        };
        prog.validate().unwrap();
        let mut params = super::super::init_params(&prog, 11);
        // Perturb LN beta/gamma away from the identity so their grads
        // exercise the full formula.
        params[15] = 0.3;
        params[19] = 1.7;
        let m = 6usize;
        let cat: Vec<i32> = (0..m * 2).map(|i| (i % 3) as i32).collect();
        let mut dense = vec![0.0f32; m];
        let mut rng = Rng::new(5);
        rng.fill_normal_f32(&mut dense, 1.0);
        let y: Vec<f32> = (0..m).map(|i| (i % 2) as f32).collect();
        let batch: Batch = vec![
            Array::I32(cat, vec![m, 2]),
            Array::F32(dense, vec![m, 1]),
            Array::F32(y, vec![m]),
        ];

        let (ref_loss, ref_grads) = loss_and_grad(&prog, &params, &batch).unwrap();

        let exec = super::super::InterpExec { prog: prog.clone() };
        let mut grads = vec![0.0f32; prog.param_dim()];
        let loss = exec
            .run_train_stream(&params, &batch, &mut grads, &mut |_, _, _| {})
            .unwrap();

        assert!((loss as f64 - ref_loss).abs() < 1e-5 * ref_loss.abs().max(1.0));
        for (i, (&g, &r)) in grads.iter().zip(&ref_grads).enumerate() {
            assert!(
                (g as f64 - r).abs() < 1e-4 * r.abs().max(1e-3),
                "grad[{i}]: interp {g} vs reference {r}"
            );
        }
    }
}
