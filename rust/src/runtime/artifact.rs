//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::runtime::interp::ProgramSpec;
use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;

/// Dtype+shape of one runtime input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        let name = j.get("name").as_str().context("io spec name")?.to_string();
        let dtype = j.get("dtype").as_str().context("io spec dtype")?.to_string();
        if dtype != "f32" && dtype != "i32" {
            bail!("unsupported dtype {dtype}");
        }
        let shape = j
            .get("shape")
            .as_arr()
            .context("io spec shape")?
            .iter()
            .map(|v| v.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(IoSpec { name, dtype, shape })
    }
}

/// Golden checksums recorded at AOT time on a deterministic batch.
#[derive(Debug, Clone, PartialEq)]
pub struct Golden {
    pub seed: u64,
    pub loss: f64,
    pub grad_sum: f64,
    pub grad_l2: f64,
}

/// One loadable artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub kind: String, // "train" | "eval" | "kernel"
    pub model: String,
    pub param_dim: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub init: BTreeMap<u64, PathBuf>,
    pub golden: Option<Golden>,
    pub meta: Json,
    /// Interpreter program description (native backend); present for the
    /// small artifacts (linreg/MLP) via aot.py emission or the builtin
    /// fallback specs.
    pub program: Option<ProgramSpec>,
}

impl ArtifactSpec {
    /// Load the initial flat parameter vector for `seed` (little-endian
    /// f32 blob; artifacts without blobs but with a program fall back to
    /// the deterministic generated init).
    pub fn load_init(&self, seed: u64) -> Result<Vec<f32>> {
        let Some(path) = self.init.get(&seed) else {
            if let Some(prog) = &self.program {
                if self.init.is_empty() {
                    // Generated init is the only parameter source here, so
                    // a missing/zero init_std would silently train from an
                    // all-zero (symmetric, gradient-dead) start — refuse.
                    if prog.layers.iter().any(|l| l.init_std <= 0.0)
                        || prog.embed.as_ref().is_some_and(|e| e.init_std <= 0.0)
                    {
                        bail!(
                            "{}: no init blobs and the program lacks positive \
                             init_std fields to generate one",
                            self.name
                        );
                    }
                    return Ok(crate::runtime::interp::init_params(prog, seed));
                }
            }
            bail!("{}: no init blob for seed {seed}", self.name);
        };
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != self.param_dim * 4 {
            bail!(
                "{}: init blob has {} bytes, expected {}",
                self.name,
                bytes.len(),
                self.param_dim * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Local batch size (first dim of the first batch input).
    pub fn local_batch(&self) -> usize {
        self.inputs.first().and_then(|s| s.shape.first().copied()).unwrap_or(0)
    }
}

/// The full artifact directory.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// True when this is the hand-written fallback manifest (no
    /// `manifest.json` on disk; interpreter-only artifacts).
    pub builtin: bool,
}

impl Manifest {
    /// Load `dir/manifest.json`, or fall back to the builtin interpreter
    /// specs when the directory has no manifest at all.
    pub fn load_or_builtin<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(crate::runtime::interp::builtin::builtin_manifest(dir))
        }
    }

    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {dir:?}/manifest.json — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| crate::err!("manifest: {e}"))?;
        let version = j.get("version").as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = BTreeMap::new();
        for (name, rec) in j.get("artifacts").as_obj().context("artifacts obj")? {
            let inputs = rec
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = rec
                .get("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let mut init = BTreeMap::new();
            if let Some(m) = rec.get("init").as_obj() {
                for (seed, p) in m {
                    init.insert(
                        seed.parse::<u64>().context("init seed key")?,
                        dir.join(p.as_str().context("init path")?),
                    );
                }
            }
            let program = match rec.get("program") {
                Json::Null => None,
                p => Some(
                    ProgramSpec::from_json(p)
                        .with_context(|| format!("artifact {name}: bad program record"))?,
                ),
            };
            let golden = rec.get("golden").as_obj().map(|_| Golden {
                seed: rec.get("golden").get("seed").as_usize().unwrap_or(0) as u64,
                loss: rec.get("golden").get("loss").as_f64().unwrap_or(f64::NAN),
                grad_sum: rec.get("golden").get("grad_sum").as_f64().unwrap_or(f64::NAN),
                grad_l2: rec.get("golden").get("grad_l2").as_f64().unwrap_or(f64::NAN),
            });
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    hlo_path: dir.join(rec.get("hlo").as_str().context("hlo path")?),
                    kind: rec.get("kind").as_str().unwrap_or("train").to_string(),
                    model: rec.get("model").as_str().unwrap_or("").to_string(),
                    param_dim: rec.get("param_dim").as_usize().unwrap_or(0),
                    inputs,
                    outputs,
                    init,
                    golden,
                    meta: rec.get("meta").clone(),
                    program,
                },
            );
        }
        Ok(Manifest {
            dir,
            artifacts,
            builtin: false,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Default artifact directory: `$ADACONS_ARTIFACTS` or `artifacts/`
    /// relative to the current directory (falling back to the crate root).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("ADACONS_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let local = PathBuf::from("artifacts");
        if local.join("manifest.json").exists() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn loads_repo_manifest_when_built() {
        let Some(m) = repo_manifest() else { return };
        let lin = m.get("linreg_b16").unwrap();
        assert_eq!(lin.param_dim, 1000);
        assert_eq!(lin.kind, "train");
        assert_eq!(lin.inputs[0].shape, vec![16, 1000]);
        assert_eq!(lin.outputs.len(), 2);
        assert_eq!(lin.local_batch(), 16);
        let init = lin.load_init(0).unwrap();
        assert_eq!(init.len(), 1000);
        assert!(init.iter().all(|x| x.is_finite()));
        assert!(lin.golden.is_some());
        assert!(m.get("missing_thing").is_err());
    }

    #[test]
    fn eval_artifacts_present() {
        let Some(m) = repo_manifest() else { return };
        let ev = m.get("mlp_cls_b32__eval").unwrap();
        assert_eq!(ev.kind, "eval");
        assert_eq!(ev.outputs.len(), 2);
    }

    #[test]
    fn load_or_builtin_falls_back_without_manifest() {
        let dir = std::env::temp_dir().join("adacons_no_manifest_here");
        let m = Manifest::load_or_builtin(&dir).unwrap();
        assert!(m.builtin);
        let lin = m.get("linreg_b16").unwrap();
        assert!(lin.program.is_some());
        assert!(lin.golden.is_some());
        assert_eq!(lin.load_init(0).unwrap().len(), 1000);
    }

    #[test]
    fn manifest_program_records_parse() {
        let dir = std::env::temp_dir().join("adacons_program_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": {"tiny": {
                 "hlo": "tiny.hlo.txt", "kind": "train", "model": "linreg",
                 "param_dim": 4,
                 "inputs": [{"name": "x", "dtype": "f32", "shape": [2, 4]}],
                 "outputs": [{"name": "loss", "dtype": "f32", "shape": []},
                             {"name": "grads", "dtype": "f32", "shape": [4]}],
                 "program": {"layers": [{"in": 4, "out": 1, "w_off": 0,
                                          "init_std": 0.5}],
                             "loss": {"kind": "mean_square"}}}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.builtin);
        let t = m.get("tiny").unwrap();
        let prog = t.program.as_ref().unwrap();
        assert_eq!(prog.param_dim(), 4);
        // No init blobs, but a program: generated init works.
        assert_eq!(t.load_init(3).unwrap().len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("adacons_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 9, "artifacts": {}}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
