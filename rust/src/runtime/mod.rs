//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! once by `make artifacts` and executes them from the training hot path.
//! Python never runs at training time.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 (what the published
//! `xla` 0.1.6 crate links) rejects jax ≥ 0.5's serialized protos
//! (64-bit instruction ids); the text parser reassigns ids.

pub mod artifact;
pub mod client;
pub mod executable;

pub use artifact::{ArtifactSpec, IoSpec, Manifest};
pub use client::Runtime;
pub use executable::Executable;
