//! Execution runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced once by `make artifacts`) and executes them
//! from the training hot path. Python never runs at training time.
//!
//! Two backends sit behind one `Runtime`/`Executable` surface:
//! * **interp** — a native-Rust interpreter ([`interp`]) driven by the
//!   manifest's `ProgramSpec` records (with builtin fallback specs for
//!   the linreg/MLP artifacts, so the default offline build trains end
//!   to end with no Python and no manifest at all);
//! * **pjrt** — XLA via the `xla` crate, gated behind the `pjrt` cargo
//!   feature (toolchain images only). Interchange is HLO **text** —
//!   xla_extension 0.5.1 (what the published `xla` 0.1.6 crate links)
//!   rejects jax ≥ 0.5's serialized protos (64-bit instruction ids); the
//!   text parser reassigns ids.

pub mod artifact;
pub mod client;
pub mod executable;
pub mod interp;

pub use artifact::{ArtifactSpec, Golden, IoSpec, Manifest};
pub use client::{Backend, Runtime};
pub use executable::Executable;
pub use interp::ProgramSpec;
