//! AdaCons — Adaptive Consensus Gradients Aggregation for Scaled
//! Distributed Training.
//!
//! Rust (L3) coordinator implementing the paper's gradient-aggregation
//! contribution plus every substrate it depends on; compute (L2 JAX model,
//! L1 Pallas kernels) is AOT-compiled to HLO and executed via PJRT.
//! See DESIGN.md for the system inventory and experiment index.

pub mod aggregation;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod parallel;
pub mod runtime;
pub mod worker;
pub mod collective;
pub mod comm;
pub mod compress;
pub mod tensor;
pub mod util;
