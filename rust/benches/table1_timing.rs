//! Table 1 bench: measured per-iteration wall time, Sum vs AdaCons, on
//! every model task — the end-to-end overhead the paper reports as
//! 1.04-1.05x. (The `adacons table table1` harness adds the simulated
//! paper-scale rows; this bench is the measured column.)

use std::sync::Arc;

use adacons::config::TrainConfig;
use adacons::coordinator::Trainer;
use adacons::optim::Schedule;
use adacons::runtime::Runtime;

fn main() -> adacons::util::error::Result<()> {
    let steps = std::env::var("BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10usize);
    if !Runtime::HAS_PJRT {
        eprintln!("built without the pjrt feature; nothing to bench");
        return Ok(());
    }
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!("== Table 1 (measured, this host): per-iteration seconds, N=8, {steps} steps ==");
    println!(
        "{:<16} {:>12} {:>12} {:>10}",
        "task", "Sum (ms)", "AdaCons (ms)", "slowdown"
    );
    for artifact in ["linreg_b64", "mlp_cls_b32", "det_b32", "dlrm_b64", "tfm_sm_b8"] {
        let mut iter_ms = Vec::new();
        for agg in ["mean", "adacons"] {
            let cfg = TrainConfig {
                artifact: artifact.into(),
                workers: 8,
                aggregator: agg.into(),
                optimizer: "sgd".into(),
                schedule: Schedule::Const { lr: 0.001 },
                steps,
                seed: 0,
                ..TrainConfig::default()
            };
            let res = Trainer::new(rt.clone(), cfg)?.run()?;
            iter_ms.push(res.wall_iter_s * 1e3);
        }
        println!(
            "{:<16} {:>12.2} {:>12.2} {:>9.3}x",
            artifact,
            iter_ms[0],
            iter_ms[1],
            iter_ms[1] / iter_ms[0]
        );
    }
    println!("\npaper: 1.04x (Imagenet), 1.04x (RetinaNet), 1.05x (DLRM), 1.04x (BERT)");
    Ok(())
}
