//! Collective-substrate bench: the data-moving ring all-reduce
//! implementation vs buffer sizes, plus the α-β closed forms it charges.

use adacons::bench::bench_auto;
use adacons::collective::{ring_allreduce, CostModel, Topology};
use adacons::util::prng::Rng;

fn main() {
    let budget = std::env::var("BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4);
    println!("== ring all-reduce (in-process data movement) ==");
    for (n, d) in [(4usize, 262_144usize), (8, 262_144), (8, 2_097_152), (32, 262_144)] {
        let mut rng = Rng::new(0);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let model = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let mut work = base.clone();
        let r = bench_auto(&format!("ring_allreduce N={n} d={d}"), budget, || {
            work.clone_from(&base);
            ring_allreduce(&mut work, &model, None);
        });
        println!(
            "{}   [{:.2} GB/s moved]",
            r.report_line(),
            r.throughput_gbps(2 * (n - 1) * (d / n) * 4 * n)
        );
    }

    println!("\n== α-β model closed forms (simulated fabric seconds) ==");
    for gbps in [100.0, 800.0] {
        for n in [8usize, 32] {
            let m = CostModel::from_topology(&Topology::ring_gbps(n, gbps));
            let d = 25_600_000; // ResNet-50 scale
            println!(
                "  {gbps:>4} Gb/s N={n:<3}: allreduce(d) {:>8.3} ms, allgather(N) {:>7.3} us, adacons iter comm {:>8.3} ms",
                m.allreduce_s(d * 4) * 1e3,
                m.allgather_s(4) * 1e6,
                m.adacons_iteration_s(d) * 1e3
            );
        }
    }
}
