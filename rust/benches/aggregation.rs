//! Aggregation micro-bench: the thread-scaling sweep over the parallel
//! engine (1/2/4/nproc threads x N workers x d), which emits the
//! machine-readable `BENCH_aggregation.json` the perf trajectory tracks,
//! plus a per-aggregator comparison at the host's full parallelism — the
//! L3 hot-path cost that Table 1's overhead column is made of.

use adacons::aggregation::{self, Aggregator};
use adacons::bench::aggregation_sweep::{run_and_write, SweepConfig};
use adacons::bench::bench_auto;
use adacons::parallel::{ParallelCtx, ParallelPolicy};
use adacons::tensor::{Buckets, GradSet};
use adacons::util::prng::Rng;

fn main() {
    let budget = std::env::var("BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4);

    // --- thread-scaling sweep (writes BENCH_aggregation.json) ---
    let sweep = SweepConfig::full(budget);
    if let Err(e) = run_and_write(&sweep, "BENCH_aggregation.json") {
        eprintln!("sweep failed: {e}");
        std::process::exit(1);
    }

    // --- per-aggregator comparison at full host parallelism ---
    let ctx = ParallelCtx::new(ParallelPolicy::default());
    println!(
        "\n== aggregator comparison ({} threads, budget {budget}s/case) ==",
        ctx.threads()
    );
    for (n, d) in [(8usize, 1_000_000usize), (32, 1_000_000)] {
        let mut rng = Rng::new(42);
        let mut gs = GradSet::zeros(n, d);
        for i in 0..n {
            rng.fill_normal_f32(gs.row_mut(i), 1.0);
        }
        let mut out = vec![0.0f32; d];
        let buckets = Buckets::single(d);
        println!("-- N={n}, d={d} ({} MB gradient matrix) --", n * d * 4 / 1_000_000);
        for name in ["mean", "adacons", "adacons-raw", "grawa", "adasum"] {
            let mut agg = aggregation::by_name(name, n).unwrap();
            let r = bench_auto(&format!("{name} N={n} d={d}"), budget, || {
                agg.aggregate_ctx(&gs, &buckets, &mut out, &ctx);
            });
            // mean reads N*d once + writes d; adacons reads ~2x for stats+proj
            println!("{}   [{:.1} GB/s]", r.report_line(), r.throughput_gbps(n * d * 4));
        }
        // robust baselines are O(N log N) per coordinate
        for name in ["median", "trimmed-mean"] {
            let mut agg = aggregation::by_name(name, n).unwrap();
            let r = bench_auto(&format!("{name} N={n} d={d}"), budget, || {
                agg.aggregate_ctx(&gs, &buckets, &mut out, &ctx);
            });
            println!("{}", r.report_line());
        }
    }
}
