//! Aggregation micro-bench: every aggregator over a (N, d) grid of
//! gradient-matrix sizes — the L3 hot-path cost that Table 1's overhead
//! column is made of. Prints mean/p50/p99 and effective memory bandwidth.

use adacons::aggregation::{self};
use adacons::bench::bench_auto;
use adacons::tensor::{Buckets, GradSet};
use adacons::util::prng::Rng;

fn main() {
    let budget = std::env::var("BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4);
    println!("== aggregation micro-bench (budget {budget}s/case) ==");
    for (n, d) in [(8usize, 1_000_000usize), (32, 1_000_000), (8, 10_000_000)] {
        let mut rng = Rng::new(42);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0f32; d];
        let buckets = Buckets::single(d);
        println!("-- N={n}, d={d} ({} MB gradient matrix) --", n * d * 4 / 1_000_000);
        for name in ["mean", "adacons", "adacons-raw", "grawa", "adasum"] {
            let mut agg = aggregation::by_name(name, n).unwrap();
            let r = bench_auto(&format!("{name} N={n} d={d}"), budget, || {
                agg.aggregate(&gs, &buckets, &mut out);
            });
            // mean reads N*d once + writes d; adacons reads ~2x for stats+proj
            println!("{}   [{:.1} GB/s]", r.report_line(), r.throughput_gbps(n * d * 4));
        }
        // robust baselines are O(N log N) per coordinate — bench smaller d
        if d <= 1_000_000 {
            for name in ["median", "trimmed-mean"] {
                let mut agg = aggregation::by_name(name, n).unwrap();
                let r = bench_auto(&format!("{name} N={n} d={d}"), budget, || {
                    agg.aggregate(&gs, &buckets, &mut out);
                });
                println!("{}", r.report_line());
            }
        }
    }
}
