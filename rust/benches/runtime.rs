//! PJRT runtime bench: per-exec latency of each artifact, plus the L1
//! consensus-kernel path (HLO via PJRT) vs the native Rust fused pass —
//! quantifying why the training hot loop uses the native implementation
//! while the Pallas kernel remains the accelerator-ready expression.

use std::sync::Arc;

use adacons::bench::bench_auto;
use adacons::data::Array;
use adacons::runtime::Runtime;
use adacons::tensor::GradSet;
use adacons::util::prng::Rng;

fn main() -> adacons::util::error::Result<()> {
    let budget = std::env::var("BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    if !Runtime::HAS_PJRT {
        eprintln!("built without the pjrt feature; nothing to bench");
        return Ok(());
    }
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            return Ok(());
        }
    };

    println!("== train-step exec latency (grad fn via PJRT, per worker call) ==");
    for name in ["linreg_b16", "mlp_cls_b32", "det_b32", "dlrm_b64", "tfm_sm_b8"] {
        let exe = rt.load(name)?;
        let params = exe.spec.load_init(0)?;
        let batch: Vec<Array> = exe
            .spec
            .inputs
            .iter()
            .map(|io| {
                let n = io.numel();
                if io.dtype == "f32" {
                    Array::F32(vec![0.5; n], io.shape.clone())
                } else {
                    Array::I32(vec![1; n], io.shape.clone())
                }
            })
            .collect();
        let r = bench_auto(&format!("exec {name} (d={})", exe.spec.param_dim), budget, || {
            exe.run_train(&params, &batch).unwrap();
        });
        println!("{}", r.report_line());
    }

    println!("\n== consensus statistics: PJRT Pallas-kernel artifact vs native Rust ==");
    let exe = rt.load("kernel_consensus_n8")?;
    let n = 8usize;
    let d = exe.spec.inputs[0].shape[1];
    let mut rng = Rng::new(1);
    let mut p = vec![0.0f32; n * d];
    rng.fill_normal_f32(&mut p, 1.0);
    let batch = vec![Array::F32(p.clone(), vec![n, d])];
    let r = bench_auto(&format!("pjrt kernel_consensus n={n} d={d}"), budget, || {
        exe.run(None, &batch).unwrap();
    });
    println!("{}   [{:.1} GB/s]", r.report_line(), r.throughput_gbps(n * d * 4));
    let gs = GradSet::from_rows(&(0..n).map(|i| p[i * d..(i + 1) * d].to_vec()).collect::<Vec<_>>());
    let r2 = bench_auto(&format!("native consensus_stats n={n} d={d}"), budget, || {
        std::hint::black_box(gs.consensus_stats());
    });
    println!("{}   [{:.1} GB/s]", r2.report_line(), r2.throughput_gbps(n * d * 4));
    println!(
        "native/pjrt speedup: {:.2}x (PJRT path carries literal-copy + dispatch overhead;\nthe kernel expresses the TPU schedule, the native pass is the CPU hot loop)",
        r.mean_s / r2.mean_s
    );
    Ok(())
}
