//! Integration: each runtime backend reproduces the golden checksums.
//!
//! `aot.py` records (loss, grad_sum, grad_l2) on a deterministic batch
//! (f32 arrays = 0.5, int arrays = index % cardinality); the builtin
//! fallback specs mint the same checksums from the straight-line f64
//! reference (`runtime::interp::reference`). The interpreter tests run
//! in **every** build — no artifacts needed; the PJRT tests keep their
//! old behaviour (skip unless `--features pjrt` and artifacts exist).

use adacons::data::Array;
use adacons::runtime::interp::golden_batch;
use adacons::runtime::{Backend, Manifest, Runtime};
use adacons::tensor::ops;

fn pjrt_runtime() -> Option<Runtime> {
    if !Runtime::HAS_PJRT {
        eprintln!("built without the pjrt feature; skipping");
        return None;
    }
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Runtime::create(dir).unwrap())
    } else {
        eprintln!("artifacts not built; skipping");
        None
    }
}

fn interp_runtime() -> Runtime {
    Runtime::open_default_with(Backend::Interp).expect("interp backend always constructs")
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-6)
}

/// The always-on golden check: the f32 interpreter must reproduce the
/// manifest goldens. Tolerances (documented in EXPERIMENTS.md §Backends):
/// the interpreter accumulates in f64 and stores f32 at layer
/// boundaries, so against the all-f64 reference the honest error is
/// ~1e-6 relative; against jax-minted goldens (real manifest) the same
/// bounds hold empirically. loss 1e-4 / grad_l2 1e-3 / grad_sum 5e-3
/// (cancellation-sensitive) leave an order of magnitude of margin.
#[test]
fn interp_train_artifacts_match_goldens() {
    let rt = interp_runtime();
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|(_, s)| {
            s.kind == "train" && s.golden.is_some() && s.program.is_some() && s.param_dim > 0
        })
        .map(|(n, _)| n.clone())
        .collect();
    if rt.manifest.builtin {
        assert_eq!(names.len(), 4, "builtin manifest: 3x linreg + mlp, {names:?}");
    }
    assert!(
        !names.is_empty(),
        "no interpretable train artifacts with goldens — regenerate artifacts \
         with the current aot.py (emits program records)"
    );
    for name in names {
        let exe = rt.load(&name).unwrap();
        let golden = exe.spec.golden.clone().unwrap();
        let params = exe.spec.load_init(golden.seed).unwrap();
        let batch = golden_batch(&exe.spec);
        let (loss, grads) = exe.run_train(&params, &batch).unwrap();
        let grad_sum = ops::sum(&grads);
        let grad_l2 = ops::sqnorm(&grads).sqrt();
        assert!(
            rel(loss as f64, golden.loss) < 1e-4,
            "{name} loss {loss} vs golden {}",
            golden.loss
        );
        assert!(
            rel(grad_l2, golden.grad_l2) < 1e-3,
            "{name} grad_l2 {grad_l2} vs {}",
            golden.grad_l2
        );
        assert!(
            rel(grad_sum, golden.grad_sum) < 5e-3,
            "{name} grad_sum {grad_sum} vs {}",
            golden.grad_sum
        );
    }
}

/// The streaming train path must produce bitwise the same gradient as the
/// one-shot path (the pipelined executor depends on this equivalence).
#[test]
fn interp_streamed_grads_match_run_train_bitwise() {
    let rt = interp_runtime();
    for name in ["linreg_b16", "mlp_cls_b32"] {
        let Ok(exe) = rt.load(name) else {
            eprintln!("{name} not interpretable in this manifest; skipping");
            continue;
        };
        let params = exe.spec.load_init(0).unwrap();
        let batch = golden_batch(&exe.spec);
        let (loss_a, grads_a) = exe.run_train(&params, &batch).unwrap();
        let mut grads_b = vec![0.0f32; exe.spec.param_dim];
        let mut segments = 0usize;
        let on_seg = &mut |_: &[f32], _: usize, _: usize| segments += 1;
        let loss_b = exe.run_train_stream(&params, &batch, &mut grads_b, on_seg).unwrap();
        assert_eq!(loss_a.to_bits(), loss_b.to_bits(), "{name}");
        assert_eq!(grads_a, grads_b, "{name}");
        assert!(segments >= 1, "{name}");
    }
}

#[test]
fn interp_eval_artifact_runs_and_shapes_match() {
    let rt = interp_runtime();
    let exe = rt.load("mlp_cls_b32__eval").unwrap();
    let params = exe.spec.load_init(0).unwrap();
    let batch = golden_batch(&exe.spec);
    let outs = exe.run(Some(&params), &batch).unwrap();
    assert_eq!(outs.len(), 2);
    let correct = outs[1].as_f32().unwrap();
    assert_eq!(correct.len(), exe.spec.inputs[0].shape[0]);
    assert!(correct.iter().all(|&c| c == 0.0 || c == 1.0));
}

#[test]
fn interp_input_validation_errors_are_caught() {
    let rt = interp_runtime();
    let exe = rt.load("linreg_b16").unwrap();
    let params = exe.spec.load_init(0).unwrap();
    // Wrong batch arity.
    assert!(exe.run(Some(&params), &vec![]).is_err());
    // Wrong param length.
    let bad = vec![0.0f32; 3];
    let batch = golden_batch(&exe.spec);
    assert!(exe.run(Some(&bad), &batch).is_err());
    // Wrong dtype.
    let wrong = vec![Array::I32(vec![0; 16 * 1000], vec![16, 1000])];
    assert!(exe.run(Some(&params), &wrong).is_err());
    // Non-interpretable artifact names fail at load with guidance.
    if rt.manifest.builtin {
        assert!(rt.load("det_b32").is_err());
    }
}

// ---------------------------------------------------------------------
// PJRT path: unchanged behaviour, self-skips without the feature or the
// built artifacts.
// ---------------------------------------------------------------------

#[test]
fn train_artifacts_match_python_goldens() {
    let Some(rt) = pjrt_runtime() else { return };
    // Every train artifact with a golden must reproduce it.
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|(_, s)| s.kind == "train" && s.golden.is_some() && s.param_dim > 0)
        // keep the fast ones in the default run; tfm_md is covered by the
        // end-to-end example
        .filter(|(n, _)| n.as_str() != "tfm_md_b4")
        .map(|(n, _)| n.clone())
        .collect();
    assert!(names.len() >= 5, "expected several train artifacts");
    for name in names {
        let exe = rt.load(&name).unwrap();
        let golden = exe.spec.golden.clone().unwrap();
        let params = exe.spec.load_init(golden.seed).unwrap();
        let batch = golden_batch(&exe.spec);
        let (loss, grads) = exe.run_train(&params, &batch).unwrap();
        let grad_sum = ops::sum(&grads);
        let grad_l2 = ops::sqnorm(&grads).sqrt();
        assert!(
            rel(loss as f64, golden.loss) < 2e-4,
            "{name} loss {} vs golden {}",
            loss,
            golden.loss
        );
        assert!(
            rel(grad_sum, golden.grad_sum) < 5e-3,
            "{name} grad_sum {grad_sum} vs {}",
            golden.grad_sum
        );
        assert!(
            rel(grad_l2, golden.grad_l2) < 1e-3,
            "{name} grad_l2 {grad_l2} vs {}",
            golden.grad_l2
        );
    }
}

#[test]
fn kernel_consensus_artifact_matches_rust_stats() {
    let Some(rt) = pjrt_runtime() else { return };
    let exe = rt.load("kernel_consensus_n8").unwrap();
    let n = 8usize;
    let d = exe.spec.inputs[0].shape[1];
    // Deterministic pseudo-random P.
    let mut rng = adacons::util::prng::Rng::new(42);
    let mut p = vec![0.0f32; n * d];
    rng.fill_normal_f32(&mut p, 1.0);
    let batch = vec![Array::F32(p.clone(), vec![n, d])];
    let outs = exe.run(None, &batch).unwrap();
    let dots = outs[0].as_f32().unwrap();
    let sqn = outs[1].as_f32().unwrap();
    let gs = adacons::tensor::GradSet::from_rows(
        &(0..n).map(|i| p[i * d..(i + 1) * d].to_vec()).collect::<Vec<_>>(),
    );
    let st = gs.consensus_stats();
    for i in 0..n {
        let rel = (dots[i] as f64 - st.dots[i]).abs() / st.dots[i].abs().max(1.0);
        assert!(rel < 1e-3, "dots[{i}]: {} vs {}", dots[i], st.dots[i]);
        let rel = (sqn[i] as f64 - st.sqn[i]).abs() / st.sqn[i];
        assert!(rel < 1e-4, "sqn[{i}]");
    }
}

#[test]
fn kernel_wsum_artifact_matches_rust_weighted_sum() {
    let Some(rt) = pjrt_runtime() else { return };
    let exe = rt.load("kernel_wsum_n8").unwrap();
    let n = 8usize;
    let d = exe.spec.inputs[1].shape[1];
    let mut rng = adacons::util::prng::Rng::new(7);
    let mut p = vec![0.0f32; n * d];
    rng.fill_normal_f32(&mut p, 1.0);
    let gamma: Vec<f32> = (0..n).map(|i| 0.1 * i as f32 - 0.3).collect();
    let batch = vec![
        Array::F32(gamma.clone(), vec![n]),
        Array::F32(p.clone(), vec![n, d]),
    ];
    let outs = exe.run(None, &batch).unwrap();
    let got = outs[0].as_f32().unwrap();
    let gs = adacons::tensor::GradSet::from_rows(
        &(0..n).map(|i| p[i * d..(i + 1) * d].to_vec()).collect::<Vec<_>>(),
    );
    let mut want = vec![0.0f32; d];
    gs.weighted_sum_into(&gamma, &mut want);
    for j in (0..d).step_by(997) {
        assert!((got[j] - want[j]).abs() < 1e-3, "j={j}");
    }
}
