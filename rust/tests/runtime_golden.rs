//! Integration: the PJRT runtime reproduces the Python-side goldens.
//!
//! `aot.py` records (loss, grad_sum, grad_l2) on a deterministic batch
//! (f32 arrays = 0.5, int arrays = index % cardinality). We regenerate
//! that batch bit-identically here, execute the compiled HLO, and compare.

use adacons::data::{Array, Batch};
use adacons::runtime::{ArtifactSpec, Manifest, Runtime};
use adacons::tensor::ops;

fn golden_batch(spec: &ArtifactSpec) -> Batch {
    spec.inputs
        .iter()
        .map(|io| {
            let n: usize = io.numel();
            if io.dtype == "f32" {
                Array::F32(vec![0.5; n], io.shape.clone())
            } else {
                let card = match io.name.as_str() {
                    "y" => spec.meta.get("classes").as_usize().unwrap_or(2),
                    "cat" | "tokens" => spec.meta.get("vocab").as_usize().unwrap_or(2),
                    _ => 2,
                } as i64;
                Array::I32(
                    (0..n as i64).map(|i| (i % card) as i32).collect(),
                    io.shape.clone(),
                )
            }
        })
        .collect()
}

fn runtime() -> Option<Runtime> {
    if !Runtime::HAS_PJRT {
        eprintln!("built without the pjrt feature; skipping");
        return None;
    }
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Runtime::create(dir).unwrap())
    } else {
        eprintln!("artifacts not built; skipping");
        None
    }
}

#[test]
fn train_artifacts_match_python_goldens() {
    let Some(rt) = runtime() else { return };
    // Every train artifact with a golden must reproduce it.
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|(_, s)| s.kind == "train" && s.golden.is_some() && s.param_dim > 0)
        // keep the fast ones in the default run; tfm_md is covered by the
        // end-to-end example
        .filter(|(n, _)| n.as_str() != "tfm_md_b4")
        .map(|(n, _)| n.clone())
        .collect();
    assert!(names.len() >= 5, "expected several train artifacts");
    for name in names {
        let exe = rt.load(&name).unwrap();
        let golden = exe.spec.golden.clone().unwrap();
        let params = exe.spec.load_init(golden.seed).unwrap();
        let batch = golden_batch(&exe.spec);
        let (loss, grads) = exe.run_train(&params, &batch).unwrap();
        let grad_sum = ops::sum(&grads);
        let grad_l2 = ops::sqnorm(&grads).sqrt();
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-6);
        assert!(
            rel(loss as f64, golden.loss) < 2e-4,
            "{name} loss {} vs golden {}",
            loss,
            golden.loss
        );
        assert!(
            rel(grad_sum, golden.grad_sum) < 5e-3,
            "{name} grad_sum {grad_sum} vs {}",
            golden.grad_sum
        );
        assert!(
            rel(grad_l2, golden.grad_l2) < 1e-3,
            "{name} grad_l2 {grad_l2} vs {}",
            golden.grad_l2
        );
    }
}

#[test]
fn kernel_consensus_artifact_matches_rust_stats() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("kernel_consensus_n8").unwrap();
    let n = 8usize;
    let d = exe.spec.inputs[0].shape[1];
    // Deterministic pseudo-random P.
    let mut rng = adacons::util::prng::Rng::new(42);
    let mut p = vec![0.0f32; n * d];
    rng.fill_normal_f32(&mut p, 1.0);
    let batch = vec![Array::F32(p.clone(), vec![n, d])];
    let outs = exe.run(None, &batch).unwrap();
    let dots = outs[0].as_f32().unwrap();
    let sqn = outs[1].as_f32().unwrap();
    let gs = adacons::tensor::GradSet::from_rows(
        &(0..n).map(|i| p[i * d..(i + 1) * d].to_vec()).collect::<Vec<_>>(),
    );
    let st = gs.consensus_stats();
    for i in 0..n {
        let rel = (dots[i] as f64 - st.dots[i]).abs() / st.dots[i].abs().max(1.0);
        assert!(rel < 1e-3, "dots[{i}]: {} vs {}", dots[i], st.dots[i]);
        let rel = (sqn[i] as f64 - st.sqn[i]).abs() / st.sqn[i];
        assert!(rel < 1e-4, "sqn[{i}]");
    }
}

#[test]
fn kernel_wsum_artifact_matches_rust_weighted_sum() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("kernel_wsum_n8").unwrap();
    let n = 8usize;
    let d = exe.spec.inputs[1].shape[1];
    let mut rng = adacons::util::prng::Rng::new(7);
    let mut p = vec![0.0f32; n * d];
    rng.fill_normal_f32(&mut p, 1.0);
    let gamma: Vec<f32> = (0..n).map(|i| 0.1 * i as f32 - 0.3).collect();
    let batch = vec![
        Array::F32(gamma.clone(), vec![n]),
        Array::F32(p.clone(), vec![n, d]),
    ];
    let outs = exe.run(None, &batch).unwrap();
    let got = outs[0].as_f32().unwrap();
    let gs = adacons::tensor::GradSet::from_rows(
        &(0..n).map(|i| p[i * d..(i + 1) * d].to_vec()).collect::<Vec<_>>(),
    );
    let mut want = vec![0.0f32; d];
    gs.weighted_sum_into(&gamma, &mut want);
    for j in (0..d).step_by(997) {
        assert!((got[j] - want[j]).abs() < 1e-3, "j={j}");
    }
}

#[test]
fn eval_artifact_runs_and_shapes_match() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("mlp_cls_b32__eval").unwrap();
    let params = exe.spec.load_init(0).unwrap();
    let batch = golden_batch(&exe.spec);
    let outs = exe.run(Some(&params), &batch).unwrap();
    assert_eq!(outs.len(), 2);
    let correct = outs[1].as_f32().unwrap();
    assert_eq!(correct.len(), exe.spec.inputs[0].shape[0]);
    assert!(correct.iter().all(|&c| c == 0.0 || c == 1.0));
}

#[test]
fn input_validation_errors_are_caught() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("linreg_b16").unwrap();
    let params = exe.spec.load_init(0).unwrap();
    // Wrong batch arity.
    assert!(exe.run(Some(&params), &vec![]).is_err());
    // Wrong param length.
    let bad = vec![0.0f32; 3];
    let batch = golden_batch(&exe.spec);
    assert!(exe.run(Some(&bad), &batch).is_err());
    // Wrong dtype.
    let wrong = vec![Array::I32(vec![0; 16 * 1000], vec![16, 1000])];
    assert!(exe.run(Some(&params), &wrong).is_err());
}
