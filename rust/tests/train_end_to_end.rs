//! End-to-end coordinator runs on tiny configs. These are the repo's core
//! behavioural checks: training converges, AdaCons matches/beats averaging
//! on the paper's linear-regression task, Byzantine workers break the mean
//! but not the median, checkpoints restore bit-exactly.
//!
//! The default (no-feature) build runs these **always**, on the native
//! interpreter backend with the builtin fallback specs — no artifacts, no
//! Python, no self-skip. A `--features pjrt` build keeps the old
//! behaviour: run on PJRT when artifacts are built, skip otherwise.

use std::sync::Arc;

use adacons::config::TrainConfig;
use adacons::coordinator::{Checkpoint, Trainer};
use adacons::optim::Schedule;
use adacons::runtime::{Backend, Manifest, Runtime};

fn runtime() -> Option<Arc<Runtime>> {
    if Runtime::HAS_PJRT {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        return Some(Arc::new(Runtime::create(dir).unwrap()));
    }
    Some(Arc::new(
        Runtime::open_default_with(Backend::Interp).expect("interp backend always constructs"),
    ))
}

fn linreg_cfg(aggregator: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        artifact: "linreg_b16".into(),
        workers: 8,
        aggregator: aggregator.into(),
        // The paper's Fig. 2 protocol: every method gets the optimal
        // analytical step size for the Eq. 14 quadratic.
        optimizer: "linreg-exact".into(),
        schedule: Schedule::Const { lr: 0.0 },
        steps,
        seed: 3,
        ..TrainConfig::default()
    }
}

#[test]
fn linreg_converges_and_adacons_not_worse_than_mean() {
    let Some(rt) = runtime() else { return };
    let mean = Trainer::new(rt.clone(), linreg_cfg("mean", 150))
        .unwrap()
        .run()
        .unwrap();
    let ada = Trainer::new(rt.clone(), linreg_cfg("adacons", 150))
        .unwrap()
        .run()
        .unwrap();
    // Both must make strong progress from the initial loss...
    // (steepest descent on a kappa~3000 quadratic: the top mode collapses
    // immediately, the bulk grinds slowly — 5x is the honest bar here)
    assert!(mean.train_loss[0] / mean.final_train_loss(10) > 5.0);
    assert!(ada.train_loss[0] / ada.final_train_loss(10) > 5.0);
    // ...and AdaCons must not be worse than averaging (paper Fig. 2: it is
    // strictly better at N=8+; we assert the weaker, seed-robust form).
    let ratio = ada.final_train_loss(10) / mean.final_train_loss(10);
    assert!(ratio < 1.25, "adacons/mean final loss ratio {ratio}");
}

#[test]
fn all_aggregators_run_one_step_on_linreg() {
    let Some(rt) = runtime() else { return };
    for name in adacons::aggregation::ALL_NAMES {
        let mut cfg = linreg_cfg(name, 2);
        cfg.workers = 4;
        let res = Trainer::new(rt.clone(), cfg)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .run()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(res.train_loss.iter().all(|l| l.is_finite()), "{name}");
    }
}

#[test]
fn rank_threads_on_bitwise_equals_off_for_all_five_aggregators() {
    // Acceptance gate for the threaded rank runtime: `--rank-threads on`
    // (N real rank threads streaming buckets over the exchange, ingested
    // in arrival order) must produce aggregated directions bitwise-equal
    // to the round-robin path at every step — which final params and the
    // per-step loss trace verify transitively — for all five aggregator
    // families, on a ragged multi-bucket config with overlap on.
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("rank-threads parity needs the interp backend; skipping");
        return;
    }
    for name in ["mean", "adacons", "grawa", "adasum", "median"] {
        let run = |threaded: bool| {
            let mut cfg = linreg_cfg(name, 12);
            cfg.workers = 4;
            cfg.bucket_cap = Some(37); // ragged multi-bucket arrival
            cfg.overlap = true;
            cfg.rank_threads = threaded;
            Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert!(on.rank_threads && !off.rank_threads);
        assert_eq!(on.final_params, off.final_params, "{name}: params diverge");
        assert_eq!(on.train_loss, off.train_loss, "{name}: loss traces diverge");
    }
}

#[test]
fn rank_threads_keep_injector_replay_bitwise() {
    // Injector ranks fall back to compute-then-replay inside the worker;
    // that must hold on a real rank thread too (the injector RNG draws
    // in flat element order either way).
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("rank-threads parity needs the interp backend; skipping");
        return;
    }
    let run = |threaded: bool| {
        let mut cfg = linreg_cfg("median", 8);
        cfg.workers = 4;
        cfg.bucket_cap = Some(64);
        cfg.overlap = true;
        cfg.rank_threads = threaded;
        cfg.injectors = vec![(1, adacons::data::GradInjector::SignFlip)];
        Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.final_params, off.final_params);
    assert_eq!(on.train_loss, off.train_loss);
}

#[test]
fn hier_single_node_topology_is_bitwise_identical_to_flat() {
    // `--topology hier:1xN` has no inter-node fabric: the hierarchical
    // wrapper delegates to the flat scheme, and the whole run — params
    // and loss traces — must be bit-identical to `--topology flat`.
    let Some(rt) = runtime() else { return };
    use adacons::collective::TopologySpec;
    for name in ["adacons", "mean"] {
        let run = |topology: TopologySpec| {
            let mut cfg = linreg_cfg(name, 10);
            cfg.workers = 8;
            cfg.bucket_cap = Some(123);
            cfg.overlap = true;
            cfg.topology = topology;
            Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
        };
        let flat = run(TopologySpec::Flat);
        let single = run(TopologySpec::Hier { nodes: 1, gpus: 8 });
        assert_eq!(flat.final_params, single.final_params, "{name}: params");
        assert_eq!(flat.train_loss, single.train_loss, "{name}: losses");
        assert_eq!(single.topology, "hier:1x8");
    }
}

#[test]
fn hier_topology_trains_and_reports_comm_split() {
    // A real two-level run: converges like flat (statistically — the
    // consensus geometry differs, so not bitwise), reports the
    // intra/inter exposed-comm split, and rank-threads parity holds.
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("hier parity needs the interp backend; skipping");
        return;
    }
    use adacons::collective::TopologySpec;
    let run = |threaded: bool| {
        let mut cfg = linreg_cfg("adacons", 12);
        cfg.workers = 8;
        cfg.bucket_cap = Some(97);
        cfg.overlap = true;
        cfg.rank_threads = threaded;
        cfg.topology = TopologySpec::Hier { nodes: 2, gpus: 4 };
        Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
    };
    let off = run(false);
    assert_eq!(off.topology, "hier:2x4");
    assert!(off.train_loss.iter().all(|l| l.is_finite()));
    assert!(*off.train_loss.last().unwrap() < off.train_loss[0]);
    // The two-level timeline accounts exposed comm per fabric level.
    assert!(off.exposed_inter_comm_s > 0.0);
    assert!(off.exposed_intra_comm_s >= 0.0);
    assert!(off.exposed_comm_s <= off.serial_comm_s + 1e-15);
    // Threaded rank execution (grouped exchange, observed readiness)
    // stays bitwise-equal to round-robin on the hierarchical path.
    let on = run(true);
    assert_eq!(on.final_params, off.final_params, "hier rank-threads params");
    assert_eq!(on.train_loss, off.train_loss, "hier rank-threads losses");
}

fn dlrm_cfg(aggregator: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        artifact: "dlrm_lite".into(),
        workers: 3,
        aggregator: aggregator.into(),
        optimizer: "adam".into(),
        schedule: Schedule::Const { lr: 0.002 },
        steps,
        seed: 9,
        ..TrainConfig::default()
    }
}

#[test]
fn dlrm_lite_trains_under_all_five_aggregators_with_rank_thread_parity() {
    // The embedding + layernorm workload end-to-end: every aggregator
    // family must train it, and `--rank-threads on` must stay bitwise
    // equal to round-robin — the embedding scatter-add and layernorm
    // backward run inside the streamed per-rank backward, so any
    // order-instability there would surface here.
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("dlrm_lite is interpreter-only; skipping");
        return;
    }
    for name in ["mean", "adacons", "grawa", "adasum", "median"] {
        let run = |threaded: bool| {
            let mut cfg = dlrm_cfg(name, 4);
            cfg.bucket_cap = Some(40_000); // multi-bucket: table splits from the dense chain
            cfg.overlap = true;
            cfg.rank_threads = threaded;
            Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert!(off.train_loss.iter().all(|l| l.is_finite()), "{name}");
        assert_eq!(on.final_params, off.final_params, "{name}: params diverge");
        assert_eq!(on.train_loss, off.train_loss, "{name}: loss traces diverge");
    }
}

#[test]
fn dlrm_lite_learns_and_reports_auc() {
    // BCE starts near ln 2 on balanced labels; a short run must push the
    // train loss down and the eval path must pool scores into an AUC
    // comfortably above chance on the planted-logit CTR stream.
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("dlrm_lite is interpreter-only; skipping");
        return;
    }
    let mut cfg = dlrm_cfg("adacons", 80);
    cfg.eval_every = 79;
    cfg.eval_batches = 2;
    let res = Trainer::new(rt, cfg).unwrap().run().unwrap();
    assert_eq!(res.metric_name, "auc");
    assert!(*res.train_loss.last().unwrap() < res.train_loss[0]);
    let auc = res.final_metric().unwrap();
    assert!(auc > 0.6, "auc {auc}");
}

#[test]
fn byzantine_worker_breaks_mean_but_not_median() {
    let Some(rt) = runtime() else { return };
    let inject = |agg: &str| {
        let mut cfg = linreg_cfg(agg, 60);
        // Fixed-lr SGD: exact line search would rescue the mean (a flipped
        // direction just gets a negative optimal step), which is not the
        // deployment regime the attack targets.
        cfg.optimizer = "sgd".into();
        cfg.schedule = Schedule::Const { lr: 0.003 };
        cfg.workers = 5;
        cfg.injectors = vec![(
            0,
            adacons::data::GradInjector::Scale(-50.0), // adversarial ascent
        )];
        Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
    };
    let mean = inject("mean");
    let median = inject("median");
    // Median converges despite the attacker...
    let med_final = median.final_train_loss(10);
    assert!(med_final.is_finite() && med_final < 0.3 * median.train_loss[0],
        "median failed to converge under attack: {med_final}");
    // ...while the mean is dragged away (diverged or >=5x worse).
    let mean_final = mean.final_train_loss(10);
    assert!(
        !mean_final.is_finite() || mean_final > 5.0 * med_final,
        "mean {mean_final} vs median {med_final}"
    );
}

#[test]
fn heterogeneous_shards_still_train_mlp() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        artifact: "mlp_cls_b32".into(),
        workers: 4,
        aggregator: "adacons".into(),
        // Scale-invariant optimizer — see exp::fig3's rationale.
        optimizer: "adam".into(),
        schedule: Schedule::Const { lr: 0.004 },
        steps: 50,
        eval_every: 49,
        eval_batches: 4,
        heterogeneity: 0.5,
        seed: 5,
        ..TrainConfig::default()
    };
    let res = Trainer::new(rt, cfg).unwrap().run().unwrap();
    assert_eq!(res.metric_name, "accuracy");
    let acc = res.final_metric().unwrap();
    // 16 classes, chance = 6.25%; 50 steps should beat chance comfortably.
    assert!(acc > 0.2, "accuracy {acc}");
    assert!(*res.train_loss.last().unwrap() < res.train_loss[0]);
}

#[test]
fn checkpoint_restore_is_bit_exact() {
    // Full-state checkpoints: a 10-step run checkpointed and resumed for
    // 10 more must land bitwise on the uninterrupted 20-step run (the
    // aggregator's EMA momentum rides the checkpoint, and the resumed
    // workers fast-forward their data streams past the completed steps).
    let Some(rt) = runtime() else { return };
    let full = Trainer::new(rt.clone(), linreg_cfg("adacons-norm", 20))
        .unwrap()
        .run()
        .unwrap();
    let mut t_a = Trainer::new(rt.clone(), linreg_cfg("adacons-norm", 10)).unwrap();
    let a = t_a.run().unwrap();
    let ck = t_a.checkpoint().unwrap();
    assert_eq!(ck.step, 10);
    assert_eq!(ck.params, a.final_params);
    let dir = std::env::temp_dir().join("adacons_e2e_ckpt");
    let path = dir.join("t.ckpt");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded, ck);
    let mut t_b = Trainer::new(rt.clone(), linreg_cfg("adacons-norm", 10)).unwrap();
    t_b.restore(&loaded).unwrap();
    let b = t_b.run().unwrap();
    assert!(b.train_loss.iter().all(|l| l.is_finite()));
    assert_eq!(b.final_params, full.final_params, "resume diverged from the fault-free run");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sim_clock_reports_adacons_overhead() {
    let Some(rt) = runtime() else { return };
    let mean = Trainer::new(rt.clone(), linreg_cfg("mean", 10))
        .unwrap()
        .run()
        .unwrap();
    let ada = Trainer::new(rt.clone(), linreg_cfg("adacons", 10))
        .unwrap()
        .run()
        .unwrap();
    // AdaCons issues an extra all-reduce: simulated iteration time must be
    // strictly larger, but bounded (compute dominates).
    assert!(ada.sim_iter_s > mean.sim_iter_s);
    assert!(ada.sim_iter_s < mean.sim_iter_s * 3.0);
}
