//! Observability acceptance tests: tracing must be bitwise-invisible to
//! training, the Chrome-trace export must reconstruct the reported comm
//! accounting to the bit, and every sink (TrainResult, jsonl, metrics
//! exposition) must agree exactly because all derive from one registry.

use std::sync::Arc;

use adacons::collective::TopologySpec;
use adacons::config::TrainConfig;
use adacons::coordinator::Trainer;
use adacons::obs::chrome::{check_trace, cross_check_metrics};
use adacons::obs::TraceLevel;
use adacons::optim::Schedule;
use adacons::runtime::{Backend, Manifest, Runtime};
use adacons::util::json::Json;

fn runtime() -> Option<Arc<Runtime>> {
    if Runtime::HAS_PJRT {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        return Some(Arc::new(Runtime::create(dir).unwrap()));
    }
    Some(Arc::new(
        Runtime::open_default_with(Backend::Interp).expect("interp backend always constructs"),
    ))
}

fn linreg_cfg(aggregator: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        artifact: "linreg_b16".into(),
        workers: 8,
        aggregator: aggregator.into(),
        optimizer: "linreg-exact".into(),
        schedule: Schedule::Const { lr: 0.0 },
        steps,
        seed: 3,
        bucket_cap: Some(97), // ragged multi-bucket
        overlap: true,
        ..TrainConfig::default()
    }
}

/// Tracing on — even at the most verbose level — must leave training
/// output bitwise-unchanged: recording reads already-computed values and
/// draws no RNG. Checked for every aggregator family on flat and
/// two-level topologies, round-robin and real rank threads.
#[test]
fn tracing_at_rank_level_is_bitwise_invisible() {
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("bitwise parity sweep needs the interp backend; skipping");
        return;
    }
    for name in ["mean", "adacons", "grawa", "adasum", "median"] {
        for topology in [TopologySpec::Flat, TopologySpec::Hier { nodes: 2, gpus: 4 }] {
            for threaded in [false, true] {
                let run = |level: TraceLevel| {
                    let mut cfg = linreg_cfg(name, 6);
                    cfg.topology = topology;
                    cfg.rank_threads = threaded;
                    cfg.trace_level = level;
                    Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
                };
                let off = run(TraceLevel::Off);
                let on = run(TraceLevel::Rank);
                let tag = format!("{name}/{topology:?}/threads={threaded}");
                assert_eq!(on.final_params, off.final_params, "{tag}: params diverge");
                assert_eq!(on.train_loss, off.train_loss, "{tag}: loss traces diverge");
            }
        }
    }
}

/// The acceptance gate: a traced hierarchical run writes a Chrome trace
/// whose transfer spans reconstruct the reported exposed-comm split to
/// the bit, a metrics exposition whose totals match the trace and the
/// `TrainResult` exactly, and a jsonl log whose per-round records re-sum
/// to the same totals — while the training output stays bitwise equal to
/// the untraced twin.
#[test]
fn bucket_trace_and_metrics_reconstruct_train_result_to_the_bit() {
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("hier acceptance run needs the interp backend; skipping");
        return;
    }
    let dir = std::env::temp_dir().join("adacons_obs_accept");
    std::fs::create_dir_all(&dir).unwrap();
    let t_path = dir.join("t.json");
    let m_path = dir.join("metrics.txt");
    let j_path = dir.join("log.jsonl");
    let steps = 6usize;
    let mk = || {
        let mut cfg = linreg_cfg("adacons", steps);
        cfg.topology = TopologySpec::Hier { nodes: 2, gpus: 4 };
        cfg
    };

    let untraced = Trainer::new(rt.clone(), mk()).unwrap().run().unwrap();
    let mut cfg = mk();
    cfg.trace_level = TraceLevel::Bucket;
    cfg.trace_out = Some(t_path.to_str().unwrap().into());
    cfg.metrics_out = Some(m_path.to_str().unwrap().into());
    cfg.jsonl = Some(j_path.to_str().unwrap().into());
    let mut tr = Trainer::new(rt.clone(), cfg).unwrap();
    let res = tr.run().unwrap();

    // Tracing on changes nothing about the training output.
    assert_eq!(res.final_params, untraced.final_params, "traced params diverge");
    assert_eq!(res.train_loss, untraced.train_loss, "traced losses diverge");

    // The exported trace parses, validates (monotonic sim timeline,
    // well-nested tracks), and replays the executor's accounting.
    let text = std::fs::read_to_string(&t_path).unwrap();
    let doc = Json::parse(&text).unwrap();
    let st = check_trace(&doc).unwrap();
    assert_eq!(st.trace_level, "bucket");
    assert_eq!(st.marks, steps, "one step mark per sync round");
    assert_eq!(st.reconstructed_steps, steps, "every mark replayed from spans");
    assert!(st.transfer_spans > 0, "hier run must record transfer spans");
    assert!(st.spans > 0 && st.events > st.spans);

    // Transfer spans reconstruct the reported comm split to the bit:
    // TrainResult divides the same registry totals by the same step count.
    let div = steps as f64;
    for (tag, trace_total, reported) in [
        ("exposed", st.exposed_comm_total, res.exposed_comm_s),
        ("intra", st.exposed_intra_total, res.exposed_intra_comm_s),
        ("inter", st.exposed_inter_total, res.exposed_inter_comm_s),
        ("serial", st.serial_comm_total, res.serial_comm_s),
    ] {
        assert_eq!(
            (trace_total / div).to_bits(),
            reported.to_bits(),
            "{tag}: trace-reconstructed mean != TrainResult"
        );
    }
    assert!(res.exposed_inter_comm_s > 0.0, "two-level run exposes inter comm");
    assert_eq!(st.wire_bytes_total, res.total_wire_bytes);

    // The metrics exposition is the registry verbatim, and its totals
    // match the trace bitwise (5 cross-checked keys).
    let exposition = std::fs::read_to_string(&m_path).unwrap();
    assert_eq!(exposition, tr.obs().metrics.expose(), "metrics file != live registry");
    assert_eq!(cross_check_metrics(&st, &exposition).unwrap(), 5);

    // The jsonl log re-sums to the same totals: each record carries the
    // round's registry deltas, so an in-order fold is the registry fold.
    let jtext = std::fs::read_to_string(&j_path).unwrap();
    let recs: Vec<Json> = jtext
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(recs.len(), steps, "one jsonl record per sync round");
    for key in [
        "step",
        "train_loss",
        "lr",
        "sim_time_s",
        "exposed_comm_s",
        "exposed_intra_comm_s",
        "exposed_inter_comm_s",
        "wire_bytes",
        "local_steps",
        "aggregator",
    ] {
        assert!(!recs[0].get(key).is_null(), "jsonl record missing {key}");
    }
    let mut exposed = 0.0f64;
    let mut inter = 0.0f64;
    let mut wire = 0u64;
    for r in &recs {
        exposed += r.get("exposed_comm_s").as_f64().unwrap();
        inter += r.get("exposed_inter_comm_s").as_f64().unwrap();
        wire += r.get("wire_bytes").as_f64().unwrap() as u64;
    }
    assert_eq!(exposed.to_bits(), st.exposed_comm_total.to_bits(), "jsonl exposed sum");
    assert_eq!(inter.to_bits(), st.exposed_inter_total.to_bits(), "jsonl inter sum");
    assert_eq!(wire, res.total_wire_bytes, "jsonl wire-byte sum");

    // Registry == TrainResult directly (no trace in between).
    let m = &tr.obs().metrics;
    assert_eq!(
        (m.total_f("exposed_comm_s") / div).to_bits(),
        res.exposed_comm_s.to_bits()
    );
    assert_eq!(m.total_u("wire_bytes"), res.total_wire_bytes);
    assert_eq!(m.total_u("sync_rounds") as usize, res.sync_rounds);
    std::fs::remove_dir_all(&dir).ok();
}

/// Rank-level tracing records the modeled backward of every rank every
/// step, and — with overlap on — a readiness instant for every
/// (rank, bucket) pair, so span counts are exactly steps x ranks and
/// steps x ranks x buckets.
#[test]
fn rank_level_span_counts_match_steps_ranks_buckets() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("adacons_obs_counts");
    std::fs::create_dir_all(&dir).unwrap();
    let t_path = dir.join("t.json");
    let (steps, workers, cap) = (5usize, 4usize, 37usize);
    let mut cfg = linreg_cfg("adacons", steps);
    cfg.workers = workers;
    cfg.bucket_cap = Some(cap);
    cfg.trace_level = TraceLevel::Rank;
    cfg.trace_out = Some(t_path.to_str().unwrap().into());
    let res = Trainer::new(rt, cfg).unwrap().run().unwrap();

    let buckets = res.final_params.len().div_ceil(cap);
    assert!(buckets >= 2, "config must split into multiple buckets");
    let doc = Json::parse(&std::fs::read_to_string(&t_path).unwrap()).unwrap();
    let st = check_trace(&doc).unwrap();
    assert_eq!(st.trace_level, "rank");
    assert_eq!(st.sim_compute_spans, steps * workers, "one SimCompute per rank per step");
    assert_eq!(
        st.bucket_ready_instants,
        steps * workers * buckets,
        "one readiness instant per (rank, bucket) per step"
    );
    assert_eq!(st.marks, steps);
    assert_eq!(st.reconstructed_steps, steps);
    std::fs::remove_dir_all(&dir).ok();
}

/// `check_trace` is a verifier, not a pretty-printer: a renamed top-level
/// key and a corrupted transfer duration must both fail loudly (the
/// latter because the replayed accounting no longer matches the step
/// marks bit-for-bit).
#[test]
fn trace_check_rejects_corrupted_traces() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("adacons_obs_corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let t_path = dir.join("t.json");
    let mut cfg = linreg_cfg("adacons", 3);
    cfg.trace_level = TraceLevel::Bucket;
    cfg.trace_out = Some(t_path.to_str().unwrap().into());
    Trainer::new(rt, cfg).unwrap().run().unwrap();
    let text = std::fs::read_to_string(&t_path).unwrap();
    let clean = Json::parse(&text).unwrap();
    check_trace(&clean).unwrap();

    // (a) Not a Chrome trace at all.
    let mut renamed = clean.clone();
    if let Json::Obj(map) = &mut renamed {
        let evs = map.remove("traceEvents").unwrap();
        map.insert("traceEventz".into(), evs);
    }
    assert!(check_trace(&renamed).is_err(), "renamed traceEvents must fail");

    // (b) Perturb one transfer span's exact duration: the reconstruction
    // replays the executor's fold from span args, so the totals no longer
    // match the step mark bitwise.
    let mut perturbed = clean.clone();
    let mut hit = false;
    if let Json::Obj(map) = &mut perturbed {
        if let Some(Json::Arr(evs)) = map.get_mut("traceEvents") {
            for ev in evs.iter_mut() {
                let Json::Obj(fields) = ev else { continue };
                let is_transfer = matches!(
                    fields.get("args").and_then(|a| match a {
                        Json::Obj(m) => m.get("kind"),
                        _ => None,
                    }),
                    Some(Json::Str(k)) if k.as_str() == "transfer"
                );
                if !is_transfer {
                    continue;
                }
                if let Some(Json::Obj(args)) = fields.get_mut("args") {
                    if let Some(Json::Num(d)) = args.get_mut("dur_s") {
                        *d += 1.0;
                        hit = true;
                        break;
                    }
                }
            }
        }
    }
    assert!(hit, "trace has no transfer span to corrupt");
    assert!(
        check_trace(&perturbed).is_err(),
        "corrupted transfer duration must fail reconstruction"
    );
    std::fs::remove_dir_all(&dir).ok();
}
