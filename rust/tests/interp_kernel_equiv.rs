//! Bitwise equivalence of the fast interpreter matmul kernels against
//! the scalar oracle.
//!
//! The blocked kernels (`ops::matmul` / `matmul_dw` / `matmul_dx`) and
//! their pool-sharded `_ctx` variants keep one f64 accumulator per
//! output element and feed it in a fixed canonical order, so their
//! results must equal the straight-loop oracle **bit for bit** on every
//! shape and at every pool width — that invariant is what lets rank
//! threads shard their backward over a shared pool without breaking the
//! `parallel_equivalence` suites. This file is the property check: a
//! deterministic grid of ragged shapes (tile-aligned, off-by-one, tiny,
//! wide, tall) crossed with pool widths, plus NaN/inf transparency.

use adacons::parallel::{ParallelCtx, ParallelPolicy};
use adacons::runtime::interp::ops::{self, oracle};
use adacons::util::prng::Rng;

/// Shapes around the MB=4 / NB=64 tile boundaries plus degenerate and
/// parallel-threshold-crossing cases.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (3, 5, 7),
    (4, 64, 64),   // exactly one tile
    (5, 65, 66),   // one past every tile edge
    (9, 66, 130),
    (13, 47, 129),
    (33, 17, 3),   // tall and narrow
    (2, 300, 11),  // long inner dimension
    (64, 32, 64),  // above the parallel threshold
];

fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn blocked_kernels_match_oracle_bitwise_on_shape_grid() {
    let mut rng = Rng::new(0xB10C);
    for &(m, k, n) in SHAPES {
        let x = fill(&mut rng, m * k);
        let w = fill(&mut rng, k * n);
        let dz = fill(&mut rng, m * n);

        let (mut a, mut b) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        ops::matmul(&x, m, k, &w, n, &mut a);
        oracle::matmul(&x, m, k, &w, n, &mut b);
        assert_eq!(bits(&a), bits(&b), "matmul ({m},{k},{n})");

        let (mut a, mut b) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
        ops::matmul_dw(&x, &dz, m, k, n, &mut a);
        oracle::matmul_dw(&x, &dz, m, k, n, &mut b);
        assert_eq!(bits(&a), bits(&b), "matmul_dw ({m},{k},{n})");

        let (mut a, mut b) = (vec![0.0f32; m * k], vec![0.0f32; m * k]);
        ops::matmul_dx(&dz, &w, m, k, n, &mut a);
        oracle::matmul_dx(&dz, &w, m, k, n, &mut b);
        assert_eq!(bits(&a), bits(&b), "matmul_dx ({m},{k},{n})");
    }
}

#[test]
fn pool_sharded_kernels_match_oracle_bitwise_at_every_width() {
    let mut rng = Rng::new(0xC0DE);
    for threads in [1usize, 2, 3, 5] {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads,
            min_shard_elems: 16,
        });
        for &(m, k, n) in SHAPES {
            let x = fill(&mut rng, m * k);
            let w = fill(&mut rng, k * n);
            let dz = fill(&mut rng, m * n);

            let (mut a, mut b) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
            ops::matmul_ctx(&ctx, &x, m, k, &w, n, &mut a);
            oracle::matmul(&x, m, k, &w, n, &mut b);
            assert_eq!(bits(&a), bits(&b), "matmul_ctx t={threads} ({m},{k},{n})");

            let (mut a, mut b) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
            ops::matmul_dw_ctx(&ctx, &x, &dz, m, k, n, &mut a);
            oracle::matmul_dw(&x, &dz, m, k, n, &mut b);
            assert_eq!(bits(&a), bits(&b), "matmul_dw_ctx t={threads} ({m},{k},{n})");

            let (mut a, mut b) = (vec![0.0f32; m * k], vec![0.0f32; m * k]);
            ops::matmul_dx_ctx(&ctx, &dz, &w, m, k, n, &mut a);
            oracle::matmul_dx(&dz, &w, m, k, n, &mut b);
            assert_eq!(bits(&a), bits(&b), "matmul_dx_ctx t={threads} ({m},{k},{n})");
        }
    }
}

#[test]
fn non_finite_values_propagate_like_the_oracle() {
    // The old kernels skipped x == 0.0 terms, which masked 0 * inf and
    // 0 * NaN; the blocked kernels are NaN-transparent. Poison one x and
    // one w entry and require bit-identical (including NaN-pattern
    // placement) results against the oracle.
    let (m, k, n) = (6usize, 66, 70);
    let mut rng = Rng::new(0xF1F1);
    let mut x = fill(&mut rng, m * k);
    let mut w = fill(&mut rng, k * n);
    let dz = fill(&mut rng, m * n);
    x[3] = 0.0;
    w[3 * n + 5] = f32::INFINITY; // 0 * inf = NaN must reach out[0*n+5]
    x[k + 7] = f32::NAN; // row 1 fully poisoned
    let (mut a, mut b) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
    ops::matmul(&x, m, k, &w, n, &mut a);
    oracle::matmul(&x, m, k, &w, n, &mut b);
    assert!(a[5].is_nan(), "0 * inf must produce NaN, got {}", a[5]);
    assert!(a[n..2 * n].iter().all(|v| v.is_nan()));
    assert_eq!(bits(&a), bits(&b));

    let (mut da, mut db) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
    ops::matmul_dw(&x, &dz, m, k, n, &mut da);
    oracle::matmul_dw(&x, &dz, m, k, n, &mut db);
    assert_eq!(bits(&da), bits(&db));

    let (mut da, mut db) = (vec![0.0f32; m * k], vec![0.0f32; m * k]);
    ops::matmul_dx(&dz, &w, m, k, n, &mut da);
    oracle::matmul_dx(&dz, &w, m, k, n, &mut db);
    assert_eq!(bits(&da), bits(&db));
}
