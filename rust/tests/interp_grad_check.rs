//! Finite-difference validation of every interpreter backward op.
//!
//! Scheme: for each forward op `f` we fix a random cotangent `C` and
//! check the analytic gradient of `L(inputs) = <C, f(inputs)>` (computed
//! by the backward op under test) against central differences of `L`.
//!
//! Precision budget, documented once here and referenced at each assert:
//! * perturbations are **snapped to the f32 grid** — we compute
//!   `x+ = f32(x + h)`, `x- = f32(x - h)` and divide by the exact f64
//!   difference `x+ - x-`, so the step itself carries no rounding error;
//! * the objective accumulates in f64 but op outputs are stored f32, so
//!   each eval carries ~1e-7 relative noise; with `h = 1e-3` that bounds
//!   the FD derivative error by ~1.5e-4, plus O(h^2) = 1e-6 truncation;
//! * inputs are O(1) draws, so we assert
//!   `|analytic - fd| < 2e-3 * max(1, |analytic|)` — an order of
//!   magnitude of margin over the budget above.

use adacons::runtime::interp::ops;
use adacons::runtime::interp::{Act, Dense, Loss, ProgramSpec};
use adacons::util::prng::Rng;

const H: f32 = 1e-3;
const TOL: f64 = 2e-3;

fn assert_close(analytic: f64, fd: f64, what: &str) {
    assert!(
        (analytic - fd).abs() < TOL * analytic.abs().max(1.0),
        "{what}: analytic {analytic} vs finite-difference {fd}"
    );
}

/// Central difference of `obj` in the `i`-th element of `x`, with the
/// step snapped to the f32 grid (see module docs).
fn central_diff(x: &[f32], i: usize, obj: &mut dyn FnMut(&[f32]) -> f64) -> f64 {
    let mut xp = x.to_vec();
    let mut xm = x.to_vec();
    xp[i] = x[i] + H;
    xm[i] = x[i] - H;
    let denom = xp[i] as f64 - xm[i] as f64;
    (obj(&xp) - obj(&xm)) / denom
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    v
}

fn dot_f64(c: &[f64], y: &[f32]) -> f64 {
    c.iter().zip(y).map(|(&cv, &yv)| cv * yv as f64).sum()
}

#[test]
fn matmul_backward_dw_and_dx() {
    let (m, k, n) = (3usize, 4, 2);
    let mut rng = Rng::new(11);
    let x = randn(&mut rng, m * k);
    let w = randn(&mut rng, k * n);
    let c: Vec<f64> = randn(&mut rng, m * n).iter().map(|&v| v as f64).collect();
    // dz for the backward ops is the cotangent C (as f32).
    let dz: Vec<f32> = c.iter().map(|&v| v as f32).collect();

    let mut dw = vec![0.0f32; k * n];
    ops::matmul_dw(&x, &dz, m, k, n, &mut dw);
    for i in 0..k * n {
        let fd = central_diff(&w, i, &mut |wp| {
            let mut out = vec![0.0f32; m * n];
            ops::matmul(&x, m, k, wp, n, &mut out);
            dot_f64(&c, &out)
        });
        assert_close(dw[i] as f64, fd, &format!("matmul dw[{i}]"));
    }

    let mut dx = vec![0.0f32; m * k];
    ops::matmul_dx(&dz, &w, m, k, n, &mut dx);
    for i in 0..m * k {
        let fd = central_diff(&x, i, &mut |xp| {
            let mut out = vec![0.0f32; m * n];
            ops::matmul(xp, m, k, &w, n, &mut out);
            dot_f64(&c, &out)
        });
        assert_close(dx[i] as f64, fd, &format!("matmul dx[{i}]"));
    }
}

#[test]
fn bias_add_backward_db() {
    let (m, n) = (5usize, 3);
    let mut rng = Rng::new(12);
    let h0 = randn(&mut rng, m * n);
    let b = randn(&mut rng, n);
    let c: Vec<f64> = randn(&mut rng, m * n).iter().map(|&v| v as f64).collect();
    let dz: Vec<f32> = c.iter().map(|&v| v as f32).collect();

    let mut db = vec![0.0f32; n];
    ops::bias_db(&dz, m, n, &mut db);
    for i in 0..n {
        let fd = central_diff(&b, i, &mut |bp| {
            let mut h = h0.clone();
            ops::bias_add(&mut h, m, n, bp);
            dot_f64(&c, &h)
        });
        assert_close(db[i] as f64, fd, &format!("bias db[{i}]"));
    }
}

#[test]
fn relu_backward_masks_correctly() {
    let n = 24usize;
    let mut rng = Rng::new(13);
    // Keep inputs away from the kink: FD across z = 0 measures the
    // (nonexistent) two-sided derivative there.
    let z: Vec<f32> = randn(&mut rng, n)
        .iter()
        .map(|&v| if v.abs() < 0.05 { 0.5 } else { v })
        .collect();
    let c: Vec<f64> = randn(&mut rng, n).iter().map(|&v| v as f64).collect();

    let mut h = z.clone();
    ops::relu(&mut h);
    let mut dh: Vec<f32> = c.iter().map(|&v| v as f32).collect();
    ops::relu_backward(&h, &mut dh);
    for i in 0..n {
        let fd = central_diff(&z, i, &mut |zp| {
            let mut hp = zp.to_vec();
            ops::relu(&mut hp);
            dot_f64(&c, &hp)
        });
        assert_close(dh[i] as f64, fd, &format!("relu dz[{i}]"));
    }
}

#[test]
fn sigmoid_backward() {
    let n = 24usize;
    let mut rng = Rng::new(14);
    let z = randn(&mut rng, n);
    let c: Vec<f64> = randn(&mut rng, n).iter().map(|&v| v as f64).collect();

    let mut h = z.clone();
    ops::sigmoid(&mut h);
    let mut dh: Vec<f32> = c.iter().map(|&v| v as f32).collect();
    ops::sigmoid_backward(&h, &mut dh);
    for i in 0..n {
        let fd = central_diff(&z, i, &mut |zp| {
            let mut hp = zp.to_vec();
            ops::sigmoid(&mut hp);
            dot_f64(&c, &hp)
        });
        assert_close(dh[i] as f64, fd, &format!("sigmoid dz[{i}]"));
    }
}

#[test]
fn mean_square_loss_backward() {
    let (m, n) = (6usize, 1);
    let mut rng = Rng::new(15);
    let y = randn(&mut rng, m * n);
    let mut dy = vec![0.0f32; m * n];
    ops::mean_square_loss(&y, m, n, &mut dy);
    for i in 0..m * n {
        let fd = central_diff(&y, i, &mut |yp| {
            let mut scratch = vec![0.0f32; m * n];
            ops::mean_square_loss(yp, m, n, &mut scratch)
        });
        assert_close(dy[i] as f64, fd, &format!("mean_square dy[{i}]"));
    }
}

#[test]
fn sigmoid_bce_loss_backward() {
    let m = 8usize;
    let mut rng = Rng::new(18);
    let logits = randn(&mut rng, m);
    let y: Vec<f32> = (0..m).map(|i| (i % 2) as f32).collect();
    let mut dl = vec![0.0f32; m];
    ops::sigmoid_bce_loss(&logits, &y, m, &mut dl);
    for i in 0..m {
        let fd = central_diff(&logits, i, &mut |lp| {
            let mut scratch = vec![0.0f32; m];
            ops::sigmoid_bce_loss(lp, &y, m, &mut scratch)
        });
        assert_close(dl[i] as f64, fd, &format!("sigmoid_bce dl[{i}]"));
    }
}

#[test]
fn softmax_xent_loss_backward() {
    let (m, c) = (4usize, 5);
    let mut rng = Rng::new(16);
    let logits = randn(&mut rng, m * c);
    let y: Vec<i32> = (0..m as i32).map(|i| i % c as i32).collect();
    let mut dl = vec![0.0f32; m * c];
    ops::softmax_xent_loss(&logits, &y, m, c, &mut dl);
    for i in 0..m * c {
        let fd = central_diff(&logits, i, &mut |lp| {
            let mut scratch = vec![0.0f32; m * c];
            ops::softmax_xent_loss(lp, &y, m, c, &mut scratch)
        });
        assert_close(dl[i] as f64, fd, &format!("softmax_xent dl[{i}]"));
    }
}

#[test]
fn embedding_backward_matches_fd() {
    // Repeated ids across rows so the scatter-add path (not just the
    // one-hot gather transpose) is exercised.
    let (m, fields, vocab, dim, dense_dim) = (3usize, 2, 4, 2, 2);
    let stride = fields * dim + dense_dim;
    let mut rng = Rng::new(31);
    let table = randn(&mut rng, fields * vocab * dim);
    let dense = randn(&mut rng, m * dense_dim);
    let cat: Vec<i32> = vec![1, 3, 1, 0, 2, 3]; // row-major m x fields; id 1/field 0 repeats
    let c: Vec<f64> = randn(&mut rng, m * stride).iter().map(|&v| v as f64).collect();
    let dx0: Vec<f32> = c.iter().map(|&v| v as f32).collect();

    let mut dtable = vec![0.0f32; table.len()];
    ops::embedding_backward(&dx0, &cat, m, fields, vocab, dim, dense_dim, &mut dtable);
    for i in 0..table.len() {
        let fd = central_diff(&table, i, &mut |tp| {
            let mut out = vec![0.0f32; m * stride];
            ops::embedding_forward(tp, &cat, &dense, m, fields, vocab, dim, dense_dim, &mut out);
            dot_f64(&c, &out)
        });
        assert_close(dtable[i] as f64, fd, &format!("embedding dtable[{i}]"));
    }
}

#[test]
fn layernorm_backward_matches_fd() {
    let (m, n) = (4usize, 6);
    let mut rng = Rng::new(32);
    let z = randn(&mut rng, m * n);
    let gamma: Vec<f32> = randn(&mut rng, n).iter().map(|&v| 1.0 + 0.3 * v).collect();
    let beta = randn(&mut rng, n);
    let c: Vec<f64> = randn(&mut rng, m * n).iter().map(|&v| v as f64).collect();

    // Objective <C, LN(z)> for any (z, gamma, beta) triple.
    let obj = |zp: &[f32], gp: &[f32], bp: &[f32]| -> f64 {
        let mut h = zp.to_vec();
        let mut xhat = vec![0.0f32; m * n];
        let mut rstd = vec![0.0f64; m];
        ops::layernorm_forward(&mut h, m, n, gp, bp, &mut xhat, &mut rstd);
        dot_f64(&c, &h)
    };

    // Analytic gradients from the backward op.
    let mut h = z.clone();
    let mut xhat = vec![0.0f32; m * n];
    let mut rstd = vec![0.0f64; m];
    ops::layernorm_forward(&mut h, m, n, &gamma, &beta, &mut xhat, &mut rstd);
    let mut dh: Vec<f32> = c.iter().map(|&v| v as f32).collect();
    let mut dgamma = vec![0.0f32; n];
    let mut dbeta = vec![0.0f32; n];
    ops::layernorm_backward(&mut dh, m, n, &gamma, &xhat, &rstd, &mut dgamma, &mut dbeta);

    for i in 0..m * n {
        let fd = central_diff(&z, i, &mut |zp| obj(zp, &gamma, &beta));
        assert_close(dh[i] as f64, fd, &format!("layernorm dz[{i}]"));
    }
    for i in 0..n {
        let fd = central_diff(&gamma, i, &mut |gp| obj(&z, gp, &beta));
        assert_close(dgamma[i] as f64, fd, &format!("layernorm dgamma[{i}]"));
        let fd = central_diff(&beta, i, &mut |bp| obj(&z, &gamma, bp));
        assert_close(dbeta[i] as f64, fd, &format!("layernorm dbeta[{i}]"));
    }
}

/// Composition check: the full streamed backward of a small 2-layer net
/// (relu + softmax-xent, biased layers) against FD on the train loss —
/// exercises the layer chaining, offset bookkeeping, and activation
/// backward in one pass. Same precision budget as the per-op checks.
#[test]
fn full_program_gradient_matches_fd() {
    use adacons::data::Array;
    let prog = ProgramSpec {
        embed: None,
        layers: vec![
            Dense {
                in_dim: 4,
                out_dim: 5,
                w_off: 5,
                b_off: Some(0),
                ln: None,
                act: Act::Relu,
                init_std: 0.7,
            },
            Dense {
                in_dim: 5,
                out_dim: 3,
                w_off: 28,
                b_off: Some(25),
                ln: None,
                act: Act::Linear,
                init_std: 0.7,
            },
        ],
        loss: Loss::SoftmaxXent { classes: 3 },
    };
    prog.validate().unwrap();
    let d = prog.param_dim();
    let params = adacons::runtime::interp::init_params(&prog, 21);
    let m = 6usize;
    let mut rng = Rng::new(17);
    let x = randn(&mut rng, m * 4);
    let y: Vec<i32> = (0..m as i32).map(|i| i % 3).collect();
    let batch = vec![Array::F32(x, vec![m, 4]), Array::I32(y, vec![m])];

    let exec = mk_exec(prog.clone());
    let mut grads = vec![0.0f32; d];
    let r = exec.run_train_stream(&params, &batch, &mut grads, &mut |_, _, _| {});
    r.unwrap();

    for i in 0..d {
        let fd = central_diff(&params, i, &mut |pp| {
            let mut scratch = vec![0.0f32; d];
            let r = exec.run_train_stream(pp, &batch, &mut scratch, &mut |_, _, _| {});
            r.unwrap() as f64
        });
        assert_close(grads[i] as f64, fd, &format!("program grad[{i}]"));
    }
}

/// Composition check for the sigmoid-BCE head: the full streamed backward
/// of a sigmoid hidden layer + single-logit output under the BCE train
/// loss (the det/dlrm-style head) against FD — smooth everywhere, so no
/// kink-guarding needed. Same precision budget as the per-op checks.
#[test]
fn bce_program_gradient_matches_fd() {
    use adacons::data::Array;
    let prog = ProgramSpec {
        embed: None,
        layers: vec![
            Dense {
                in_dim: 4,
                out_dim: 5,
                w_off: 5,
                b_off: Some(0),
                ln: None,
                act: Act::Sigmoid,
                init_std: 0.7,
            },
            Dense {
                in_dim: 5,
                out_dim: 1,
                w_off: 26,
                b_off: Some(25),
                ln: None,
                act: Act::Linear,
                init_std: 0.7,
            },
        ],
        loss: Loss::SigmoidBce,
    };
    prog.validate().unwrap();
    let d = prog.param_dim();
    let params = adacons::runtime::interp::init_params(&prog, 23);
    let m = 6usize;
    let mut rng = Rng::new(19);
    let x = randn(&mut rng, m * 4);
    let y: Vec<i32> = (0..m as i32).map(|i| i % 2).collect();
    let batch = vec![Array::F32(x, vec![m, 4]), Array::I32(y, vec![m])];

    let exec = mk_exec(prog.clone());
    let mut grads = vec![0.0f32; d];
    let r = exec.run_train_stream(&params, &batch, &mut grads, &mut |_, _, _| {});
    r.unwrap();

    for i in 0..d {
        let fd = central_diff(&params, i, &mut |pp| {
            let mut scratch = vec![0.0f32; d];
            let r = exec.run_train_stream(pp, &batch, &mut scratch, &mut |_, _, _| {});
            r.unwrap() as f64
        });
        assert_close(grads[i] as f64, fd, &format!("bce grad[{i}]"));
    }
}

/// Build an `InterpExec` for a bare program by wrapping it in a minimal
/// artifact spec.
fn mk_exec(prog: ProgramSpec) -> adacons::runtime::Executable {
    use adacons::runtime::{ArtifactSpec, IoSpec};
    let d = prog.param_dim();
    let spec = ArtifactSpec {
        name: "fd_check".into(),
        hlo_path: std::path::PathBuf::from("unused.hlo.txt"),
        kind: "train".into(),
        model: "mlp_cls".into(),
        param_dim: d,
        inputs: vec![
            IoSpec {
                name: "x".into(),
                dtype: "f32".into(),
                shape: vec![6, 4],
            },
            IoSpec {
                name: "y".into(),
                dtype: "i32".into(),
                shape: vec![6],
            },
        ],
        outputs: vec![
            IoSpec {
                name: "loss".into(),
                dtype: "f32".into(),
                shape: vec![],
            },
            IoSpec {
                name: "grads".into(),
                dtype: "f32".into(),
                shape: vec![d],
            },
        ],
        init: std::collections::BTreeMap::new(),
        golden: None,
        meta: adacons::util::json::Json::Null,
        program: Some(prog),
    };
    adacons::runtime::Executable::interpret(&spec).unwrap()
}
