//! Property-based tests of the aggregation algebra (DESIGN.md §5
//! invariants), driven by the in-house `util::proptest` harness.

use adacons::aggregation::{AdaCons, AdaConsConfig, Aggregator, Grawa, MeanAggregator};
use adacons::tensor::{ops, Buckets, GradSet};
use adacons::util::proptest::{run_cases, Gen};

fn random_gradset(g: &mut Gen, n_max: usize, d_max: usize) -> GradSet {
    let n = g.usize_in(2, n_max);
    let d = g.usize_in(4, d_max);
    let scale = g.f64_in(0.05, 4.0) as f32;
    GradSet::from_rows(&g.grad_matrix(n, d, scale))
}

#[test]
fn prop_norm_variant_subspace_coefficients_sum_one() {
    run_cases(60, 0xA1, |g| {
        let gs = random_gradset(g, 12, 300);
        let st = gs.consensus_stats();
        let mut agg = AdaCons::new(AdaConsConfig::norm_only());
        let (gamma, _) = agg.weights_from_stats(0, &st.dots, &st.sqn);
        let s: f64 = gamma
            .iter()
            .zip(&st.sqn)
            .map(|(&w, &q)| w as f64 * q.sqrt())
            .sum();
        // Either sum-one held (Eq. 13), or the degenerate fallback produced
        // uniform weights; detect the fallback via equal gammas.
        let uniform = gamma.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9);
        assert!((s - 1.0).abs() < 1e-4 || uniform, "sum {s}, gamma {gamma:?}");
    });
}

#[test]
fn prop_identical_gradients_collapse_all_variants() {
    run_cases(40, 0xA2, |g| {
        let d = g.usize_in(4, 200);
        let n = g.usize_in(2, 10);
        let row = g.vec_normal(d, 1.0);
        if ops::sqnorm(&row) < 1e-12 {
            return; // measure-zero degenerate case
        }
        let gs = GradSet::from_rows(&vec![row.clone(); n]);
        for cfg in [AdaConsConfig::raw(), AdaConsConfig::norm_only()] {
            let mut agg = AdaCons::new(cfg);
            let mut out = vec![0.0f32; d];
            agg.aggregate(&gs, &Buckets::single(d), &mut out);
            let norm = ops::nrm2(&row);
            for j in 0..d {
                // raw (Eq. 8, λ=1): out == mean == row.
                // norm (Eq. 13): γ_i = 1/(N||g||) -> out = g/||g||.
                let expect = if cfg.normalize {
                    row[j] as f64 / norm
                } else {
                    row[j] as f64
                };
                assert!(
                    (out[j] as f64 - expect).abs() < 2e-4 * expect.abs().max(1.0),
                    "cfg={cfg:?} j={j}: {} vs {expect}",
                    out[j]
                );
            }
        }
    });
}

#[test]
fn prop_worker_permutation_equivariance() {
    // Relabeling workers permutes γ identically (no positional bias),
    // for the stateless variants.
    run_cases(40, 0xA3, |g| {
        let gs = random_gradset(g, 8, 120);
        let n = gs.n();
        let d = gs.d();
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = g.usize_in(0, i);
            perm.swap(i, j);
        }
        let permuted =
            GradSet::from_rows(&perm.iter().map(|&i| gs.row(i).to_vec()).collect::<Vec<_>>());
        for cfg in [AdaConsConfig::raw(), AdaConsConfig::norm_only()] {
            let mut a = AdaCons::new(cfg);
            let mut b = AdaCons::new(cfg);
            let mut out_a = vec![0.0f32; d];
            let mut out_b = vec![0.0f32; d];
            let ia = a.aggregate(&gs, &Buckets::single(d), &mut out_a);
            let ib = b.aggregate(&permuted, &Buckets::single(d), &mut out_b);
            let ga = ia.gammas.unwrap();
            let gb = ib.gammas.unwrap();
            for (k, &i) in perm.iter().enumerate() {
                assert!(
                    (ga[i] - gb[k]).abs() <= 2e-4 * ga[i].abs().max(1e-3),
                    "cfg={cfg:?}: gamma[{i}]={} vs permuted gamma[{k}]={}",
                    ga[i],
                    gb[k]
                );
            }
            for j in 0..d {
                assert!((out_a[j] - out_b[j]).abs() < 1e-3 * out_a[j].abs().max(1.0));
            }
        }
    });
}

#[test]
fn prop_mean_and_grawa_weights_sum_one() {
    run_cases(40, 0xA4, |g| {
        let gs = random_gradset(g, 10, 100);
        let d = gs.d();
        let mut out = vec![0.0f32; d];
        let aggs: Vec<Box<dyn Aggregator>> =
            vec![Box::new(MeanAggregator::new()), Box::new(Grawa::new())];
        for mut agg in aggs {
            let info = agg.aggregate(&gs, &Buckets::single(d), &mut out);
            let gam = info.gammas.unwrap();
            let s: f64 = gam.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-5, "{} sum {s}", agg.name());
        }
    });
}

#[test]
fn prop_preconditioner_gram_is_psd() {
    // v^T (P^T P) v = ||P v||^2 >= 0 (paper §3.3's PSD claim probed
    // through the Gram form).
    run_cases(40, 0xA5, |g| {
        let gs = random_gradset(g, 8, 80);
        let n = gs.n();
        let gram = gs.gram();
        let probe: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0, 1.0)).collect();
        let mut quad = 0.0;
        for i in 0..n {
            for j in 0..n {
                quad += probe[i] * probe[j] * gram[i * n + j];
            }
        }
        assert!(quad >= -1e-6 * quad.abs().max(1.0), "quad {quad}");
    });
}

#[test]
fn prop_aggregate_is_descent_direction_on_consensus_bundles() {
    // When all worker gradients share a dominant common component (the
    // regime synchronous SGD operates in), <psi, g_bar> > 0 for every
    // linear aggregator — the update never ascends.
    run_cases(40, 0xA6, |g| {
        let n = g.usize_in(2, 8);
        let d = g.usize_in(8, 150);
        let common = g.vec_normal(d, 1.0);
        if ops::sqnorm(&common) < 1e-6 {
            return;
        }
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let noise = g.vec_normal(d, 0.2);
                common.iter().zip(&noise).map(|(&c, &e)| c + e).collect()
            })
            .collect();
        let gs = GradSet::from_rows(&rows);
        let mut mean_dir = vec![0.0f32; d];
        gs.mean_into(&mut mean_dir);
        for name in ["adacons", "adacons-raw", "adacons-norm", "grawa", "mean"] {
            let mut agg = adacons::aggregation::by_name(name, n).unwrap();
            let mut out = vec![0.0f32; d];
            agg.aggregate(&gs, &Buckets::single(d), &mut out);
            let ip = ops::dot(&out, &mean_dir);
            assert!(ip > 0.0, "{name}: <psi, g_bar> = {ip}");
        }
    });
}

#[test]
fn prop_momentum_stream_stays_bounded() {
    // A stationary coefficient stream through the sorted EMA never
    // diverges and stays within the stream's range.
    run_cases(30, 0xA7, |g| {
        let n = g.usize_in(2, 8);
        let mut agg = AdaCons::new(AdaConsConfig::momentum_only());
        let sqn = vec![1.0; n];
        let lo = g.f64_in(0.1, 1.0);
        let hi = lo + g.f64_in(0.1, 1.0);
        let mut last = Vec::new();
        for _ in 0..50 {
            let dots: Vec<f64> = (0..n).map(|_| g.f64_in(lo, hi)).collect();
            let (gamma, _) = agg.weights_from_stats(0, &dots, &sqn);
            last = gamma;
        }
        for &w in &last {
            assert!(w.is_finite());
            // gamma = alpha/N with alpha EMA-bounded in [lo, hi].
            assert!(w as f64 >= lo / n as f64 * 0.5 && w as f64 <= hi / n as f64 * 2.0);
        }
    });
}

#[test]
fn prop_bucketed_and_modelwise_agree_for_mean() {
    // Averaging is linear in each coordinate, so layer-wise == model-wise.
    run_cases(30, 0xA8, |g| {
        let gs = random_gradset(g, 6, 200);
        let d = gs.d();
        let cap = g.usize_in(1, d);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        MeanAggregator::new().aggregate(&gs, &Buckets::single(d), &mut a);
        MeanAggregator::new().aggregate(&gs, &Buckets::fixed(d, cap), &mut b);
        for j in 0..d {
            assert!((a[j] - b[j]).abs() < 1e-6);
        }
    });
}

#[test]
fn prop_ring_allreduce_matches_direct_sum() {
    use adacons::collective::{ring_allreduce, CostModel, Topology};
    run_cases(30, 0xA9, |g| {
        let n = g.usize_in(1, 9);
        let d = g.usize_in(1, 300);
        let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(d, 1.0)).collect();
        let expected: Vec<f32> = (0..d).map(|j| bufs.iter().map(|b| b[j]).sum()).collect();
        let mut work = bufs.clone();
        let model = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        ring_allreduce(&mut work, &model, None);
        for r in 0..n {
            for j in 0..d {
                assert!(
                    (work[r][j] - expected[j]).abs() <= 1e-3 * expected[j].abs().max(1.0),
                    "n={n} d={d} r={r} j={j}"
                );
            }
        }
    });
}
