//! Elastic fault-tolerance drills: rank death mid-step, straggler
//! cutoff, krum NaN filtering, and full-state checkpoint/resume parity.
//!
//! These run on the native interpreter backend (deterministic SimClock
//! timeline) so every fault is replayable from the printed seed. The CI
//! chaos leg loops this suite at several `--test-threads` settings.

use std::sync::Arc;

use adacons::collective::TopologySpec;
use adacons::compress::{CompressScope, CompressionSpec, CompressorKind};
use adacons::config::{CutoffSpec, TrainConfig};
use adacons::coordinator::{Checkpoint, Trainer};
use adacons::data::GradInjector;
use adacons::optim::Schedule;
use adacons::runtime::{Backend, Manifest, Runtime};

/// Every drill derives its faults from this seed; it is echoed per test so
/// a CI failure line is enough to replay the exact fault sequence.
const FAULT_SEED: u64 = 3;

fn runtime() -> Option<Arc<Runtime>> {
    if Runtime::HAS_PJRT {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        return Some(Arc::new(Runtime::create(dir).unwrap()));
    }
    Some(Arc::new(
        Runtime::open_default_with(Backend::Interp).expect("interp backend always constructs"),
    ))
}

/// Interp-only runtime: the elastic exchange and SimClock cutoff drills
/// need the in-process transport, like the rank-threads parity tests.
fn interp_runtime() -> Option<Arc<Runtime>> {
    let rt = runtime()?;
    if rt.backend() != Backend::Interp {
        eprintln!("fault drills need the interp backend; skipping");
        return None;
    }
    Some(rt)
}

fn linreg_cfg(aggregator: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        artifact: "linreg_b16".into(),
        workers: 8,
        aggregator: aggregator.into(),
        optimizer: "linreg-exact".into(),
        schedule: Schedule::Const { lr: 0.0 },
        steps,
        seed: FAULT_SEED,
        ..TrainConfig::default()
    }
}

/// An elastic config: `k`-of-`workers` cutoff on the threaded runtime
/// (the only mode the elastic exchange supports).
fn elastic_cfg(aggregator: &str, steps: usize, workers: usize, k: usize) -> TrainConfig {
    let mut cfg = linreg_cfg(aggregator, steps);
    cfg.workers = workers;
    cfg.rank_threads = true;
    cfg.overlap = false;
    cfg.cutoff = Some(CutoffSpec {
        k,
        n: workers,
        grace_ms: 0.0,
    });
    cfg
}

#[test]
fn rank_panic_mid_run_completes_from_survivors_and_rejoins() {
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = interp_runtime() else { return };
    let mut cfg = elastic_cfg("adacons", 8, 4, 3);
    cfg.cutoff = Some(CutoffSpec {
        k: 3,
        n: 4,
        grace_ms: 5.0,
    });
    // Rank 1's compute thread dies exactly at step 3; the step must
    // finalize over the 3 survivors and a fast-forwarded replacement
    // must be live again for step 4 (so exactly one degraded step).
    cfg.injectors
        .push((1, GradInjector::parse("panic-at:3").unwrap()));
    let res = Trainer::new(rt, cfg).unwrap().run().unwrap();
    assert_eq!(res.degraded_steps, 1, "only the death step is degraded");
    assert_eq!(res.rejoins, 1, "dead rank respawned exactly once");
    assert!(res.train_loss.iter().all(|l| l.is_finite()));
    assert!(
        res.train_loss[0] / res.final_train_loss(3) > 1.5,
        "training failed to make progress through the fault"
    );
}

#[test]
fn cutoff_drops_injected_straggler_every_step() {
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = interp_runtime() else { return };
    let mut cfg = elastic_cfg("mean", 10, 4, 3);
    // Rank 2 reports 50x compute time every step: with zero grace and the
    // healthy ranks finishing in deterministic lockstep, it misses the
    // deadline every step but never dies — dropped, not respawned.
    cfg.injectors
        .push((2, GradInjector::parse("delay:1:50").unwrap()));
    let res = Trainer::new(rt, cfg).unwrap().run().unwrap();
    assert_eq!(res.degraded_steps, 10, "straggler dropped every step");
    assert_eq!(res.rejoins, 0, "a slow rank is not a dead rank");
    assert!(res.train_loss.iter().all(|l| l.is_finite()));
    assert!(
        res.train_loss[0] / res.final_train_loss(3) > 1.5,
        "survivor-renormalized consensus failed to converge"
    );
}

#[test]
fn krum_filter_excludes_nan_rank_and_training_stays_finite() {
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = interp_runtime() else { return };
    let mut cfg = elastic_cfg("mean", 8, 4, 4);
    cfg.krum_f = 1;
    // Rank 2 ships all-NaN gradients every step. The outlier filter must
    // drop the non-finite row before aggregation, so the step finalizes
    // degraded (3 of 4 rows) but the model never sees a NaN.
    cfg.injectors
        .push((2, GradInjector::parse("nan:1").unwrap()));
    let res = Trainer::new(rt, cfg).unwrap().run().unwrap();
    assert_eq!(res.degraded_steps, 8, "NaN rank filtered every step");
    assert_eq!(res.rejoins, 0);
    assert!(
        res.train_loss.iter().all(|l| l.is_finite()),
        "a NaN row leaked through the krum filter: {:?}",
        res.train_loss
    );
    assert!(res.final_params.iter().all(|p| p.is_finite()));
}

/// Run `2*half` steps straight, then `half` + checkpoint + resume `half`,
/// and require the split run to land bitwise on the uninterrupted one
/// (params and the per-step loss tail), including a save/load round trip
/// through the on-disk format.
fn assert_resume_bitwise(rt: &Arc<Runtime>, cfg_half: TrainConfig, tag: &str) {
    let half = cfg_half.steps;
    let mut cfg_full = cfg_half.clone();
    cfg_full.steps = 2 * half;
    let full = Trainer::new(rt.clone(), cfg_full).unwrap().run().unwrap();

    let mut t_a = Trainer::new(rt.clone(), cfg_half.clone()).unwrap();
    let a = t_a.run().unwrap();
    let ck = t_a.checkpoint().unwrap();
    assert_eq!(ck.step, half as u64, "{tag}");
    assert_eq!(ck.params, a.final_params, "{tag}");

    let path = std::env::temp_dir().join(format!("adacons_ft_{}.ckpt", tag.replace('/', "_")));
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, ck, "{tag}: on-disk round trip not lossless");

    let mut t_b = Trainer::new(rt.clone(), cfg_half).unwrap();
    t_b.restore(&loaded).unwrap();
    let b = t_b.run().unwrap();
    assert_eq!(
        b.final_params, full.final_params,
        "{tag}: resumed params diverge from the fault-free run"
    );
    assert_eq!(
        b.train_loss[..],
        full.train_loss[half..],
        "{tag}: resumed loss tail diverges"
    );
}

#[test]
fn checkpoint_resume_bitwise_for_all_five_aggregators() {
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = runtime() else { return };
    for name in ["mean", "adacons", "grawa", "adasum", "median"] {
        assert_resume_bitwise(&rt, linreg_cfg(name, 6), name);
    }
}

#[test]
fn checkpoint_resume_bitwise_on_hier_topology_and_rank_threads() {
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = interp_runtime() else { return };
    let hier = |name: &str, threaded: bool| {
        let mut cfg = linreg_cfg(name, 6);
        cfg.topology = TopologySpec::Hier { nodes: 2, gpus: 4 };
        cfg.rank_threads = threaded;
        cfg
    };
    assert_resume_bitwise(&rt, hier("adacons", false), "hier/roundrobin");
    assert_resume_bitwise(&rt, hier("adacons", true), "hier/threaded");
    let mut flat = linreg_cfg("mean", 6);
    flat.rank_threads = true;
    assert_resume_bitwise(&rt, flat, "flat/threaded");
}

#[test]
fn checkpoint_resume_bitwise_with_per_rank_compression() {
    // int8/fp16 error-feedback residuals ride the checkpoint (the restore
    // bug this PR fixes: residuals used to be silently discarded), and the
    // int8 rng keys off the absolute step, so the resumed stream is
    // bitwise-continuous in both rank modes.
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = interp_runtime() else { return };
    for kind in [CompressorKind::Fp16, CompressorKind::Int8] {
        for threaded in [false, true] {
            let mut cfg = linreg_cfg("adacons", 6);
            cfg.compression = CompressionSpec {
                kind,
                scope: CompressScope::All,
            };
            cfg.rank_threads = threaded;
            let tag = format!("{}/{}", kind.tag(), if threaded { "thr" } else { "rr" });
            assert_resume_bitwise(&rt, cfg, &tag);
        }
    }
}

#[test]
fn resume_composes_with_elastic_cutoff() {
    // A checkpointed run restarted *into* an elastic config keeps going:
    // restore, then survive a straggler drill on the continuation.
    eprintln!("fault seed: {FAULT_SEED}");
    let Some(rt) = interp_runtime() else { return };
    let mut t_a = Trainer::new(rt.clone(), elastic_cfg("adacons", 5, 4, 3)).unwrap();
    t_a.run().unwrap();
    let ck = t_a.checkpoint().unwrap();
    let mut cfg_b = elastic_cfg("adacons", 5, 4, 3);
    cfg_b
        .injectors
        .push((0, GradInjector::parse("delay:1:50").unwrap()));
    let mut t_b = Trainer::new(rt, cfg_b).unwrap();
    t_b.restore(&ck).unwrap();
    let b = t_b.run().unwrap();
    assert_eq!(b.degraded_steps, 5);
    assert!(b.train_loss.iter().all(|l| l.is_finite()));
}
