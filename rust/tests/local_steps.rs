//! Local-step (periodic-consensus) training regime, end to end.
//!
//! Pins the regime's contract: `--local-steps 1` is bitwise-identical
//! to the historical synchronous path in every execution mode; H>1
//! delta rounds stay bitwise-equal between round-robin and real rank
//! threads; a single-rank delta round is (up to summation order) H
//! sequential SGD steps, so delta aggregation is unbiased; wire traffic
//! and serial comm amortize by exactly 1/H; the adaptive-H controller
//! is deterministic; and round-aligned checkpoints resume bitwise.

use std::sync::Arc;

use adacons::config::{LocalStepSpec, TrainConfig};
use adacons::coordinator::{Checkpoint, Trainer};
use adacons::optim::Schedule;
use adacons::runtime::{Backend, Manifest, Runtime};

fn runtime() -> Option<Arc<Runtime>> {
    if Runtime::HAS_PJRT {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        return Some(Arc::new(Runtime::create(dir).unwrap()));
    }
    Some(Arc::new(
        Runtime::open_default_with(Backend::Interp).expect("interp backend always constructs"),
    ))
}

/// Linreg on plain SGD at a real learning rate, so H>1 local passes
/// actually move the local models (the Fig. 2 `linreg-exact` protocol
/// pins lr 0.0, which would make every local pass a no-op).
fn sgd_cfg(aggregator: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        artifact: "linreg_b16".into(),
        workers: 8,
        aggregator: aggregator.into(),
        optimizer: "sgd".into(),
        schedule: Schedule::Const { lr: 0.003 },
        steps,
        seed: 3,
        ..TrainConfig::default()
    }
}

#[test]
fn explicit_h1_is_bitwise_identical_to_the_synchronous_path() {
    // The hard invariant: `--local-steps 1` takes the historical
    // synchronous path verbatim for all five aggregators, flat and
    // hierarchical, rank threads on and off — final params and the
    // per-step loss trace are bitwise-equal to a config that never
    // mentions local_steps at all.
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("rank-threads parity needs the interp backend; skipping");
        return;
    }
    use adacons::collective::TopologySpec;
    for name in ["mean", "adacons", "grawa", "adasum", "median"] {
        for topology in [TopologySpec::Flat, TopologySpec::Hier { nodes: 2, gpus: 4 }] {
            let run = |threaded: bool, explicit_h1: bool| {
                let mut cfg = sgd_cfg(name, 6);
                cfg.bucket_cap = Some(37); // ragged multi-bucket arrival
                cfg.overlap = true;
                cfg.rank_threads = threaded;
                cfg.topology = topology;
                if explicit_h1 {
                    cfg.local_steps = LocalStepSpec::parse("1").unwrap();
                }
                Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
            };
            let base = run(false, false);
            for threaded in [false, true] {
                let h1 = run(threaded, true);
                assert_eq!(h1.local_steps, "1");
                assert_eq!(h1.sync_rounds, 6);
                assert_eq!(
                    h1.final_params, base.final_params,
                    "{name}/{topology:?}/threaded={threaded}: params diverge"
                );
                assert_eq!(
                    h1.train_loss, base.train_loss,
                    "{name}/{topology:?}/threaded={threaded}: loss traces diverge"
                );
            }
        }
    }
}

#[test]
fn h4_rank_threads_bitwise_equal_roundrobin_flat_and_hier() {
    // H>1 rounds route both execution modes through the shared
    // `Worker::compute_delta_round`, so the delta matrices — and hence
    // params and the per-round loss trace — must stay bitwise-equal,
    // exactly like the synchronous parity gate.
    let Some(rt) = runtime() else { return };
    if rt.backend() != Backend::Interp {
        eprintln!("rank-threads parity needs the interp backend; skipping");
        return;
    }
    use adacons::collective::TopologySpec;
    for name in ["mean", "adacons", "median"] {
        for topology in [TopologySpec::Flat, TopologySpec::Hier { nodes: 2, gpus: 4 }] {
            let run = |threaded: bool| {
                let mut cfg = sgd_cfg(name, 8);
                cfg.bucket_cap = Some(37);
                cfg.overlap = true;
                cfg.rank_threads = threaded;
                cfg.topology = topology;
                cfg.local_steps = LocalStepSpec::parse("4").unwrap();
                Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
            };
            let on = run(true);
            let off = run(false);
            assert_eq!(on.sync_rounds, 2);
            assert_eq!(on.local_step_trace, vec![4, 4]);
            assert_eq!(
                on.final_params, off.final_params,
                "{name}/{topology:?}: params diverge"
            );
            assert_eq!(
                on.train_loss, off.train_loss,
                "{name}/{topology:?}: loss traces diverge"
            );
        }
    }
}

#[test]
fn single_rank_delta_round_is_sequential_sgd_up_to_summation_order() {
    // Unbiasedness anchor: with one rank and the mean aggregator, a
    // sync round of H local SGD passes evaluates the exact same
    // gradient sequence as H synchronous steps (each pass starts from
    // the previous pass's iterate, bitwise), and the outer update
    // θ − lr·Σ g differs from the sequential (((θ − lr·g1) − lr·g2)…)
    // only in f32 summation order. The final params must agree to
    // float-association tolerance.
    let Some(rt) = runtime() else { return };
    let run = |h: &str| {
        let mut cfg = sgd_cfg("mean", 8);
        cfg.workers = 1;
        cfg.local_steps = LocalStepSpec::parse(h).unwrap();
        Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
    };
    let sync = run("1");
    let local = run("4");
    assert_eq!(local.sync_rounds, 2);
    let (mut diff2, mut norm2) = (0.0f64, 0.0f64);
    for (a, b) in local.final_params.iter().zip(&sync.final_params) {
        diff2 += ((a - b) as f64).powi(2);
        norm2 += (*b as f64).powi(2);
    }
    let rel = (diff2 / norm2.max(1e-30)).sqrt();
    assert!(rel < 1e-4, "single-rank H=4 vs sequential SGD: rel diff {rel}");
    // And both runs actually train.
    assert!(*sync.train_loss.last().unwrap() < sync.train_loss[0]);
    assert!(*local.train_loss.last().unwrap() < local.train_loss[0]);
}

#[test]
fn wire_bytes_and_serial_comm_amortize_by_exactly_h() {
    // The perf contract: at fixed local-step count, H=4 issues exactly
    // 1/4 of the collective traffic (payload bytes are data-independent)
    // and 1/4 of the amortized serial/exposed comm seconds (barrier
    // accounting prices ops purely from the α-β model). Training must
    // still converge on the uneven (heterogeneous) shards.
    let Some(rt) = runtime() else { return };
    let run = |h: &str| {
        let mut cfg = sgd_cfg("adacons", 16);
        cfg.bucket_cap = Some(64);
        cfg.overlap = false; // barrier semantics: deterministic comm seconds
        cfg.heterogeneity = 0.5; // uneven per-rank shard distributions
        cfg.local_steps = LocalStepSpec::parse(h).unwrap();
        Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
    };
    let h1 = run("1");
    let h4 = run("4");
    assert_eq!(h1.sync_rounds, 16);
    assert_eq!(h4.sync_rounds, 4);
    assert!(h1.total_wire_bytes > 0);
    assert_eq!(
        h1.total_wire_bytes,
        4 * h4.total_wire_bytes,
        "wire traffic must amortize by exactly H"
    );
    let ratio = h1.serial_comm_s / h4.serial_comm_s;
    assert!(
        (ratio - 4.0).abs() < 1e-6,
        "serial comm amortization ratio {ratio}, want 4"
    );
    // Barrier mode: every transfer is exposed.
    assert!((h4.exposed_comm_s - h4.serial_comm_s).abs() < 1e-15);
    // Delta aggregation still trains on uneven shards.
    assert!(*h4.train_loss.last().unwrap() < h4.train_loss[0]);
    assert!(h4.train_loss.iter().all(|l| l.is_finite()));
}

#[test]
fn adaptive_h_trace_is_deterministic_and_bounded() {
    // `auto:<min>-<max>`: the controller is a pure function of
    // aggregation outputs, so two identical runs must realize the same
    // H trace (and the same params); every realized H respects the
    // bounds and the trace partitions the local-step budget exactly.
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut cfg = sgd_cfg("adacons", 24);
        cfg.local_steps = LocalStepSpec::parse("auto:1-8").unwrap();
        Trainer::new(rt.clone(), cfg).unwrap().run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.local_steps, "auto:1-8");
    assert_eq!(a.local_step_trace, b.local_step_trace, "H trace not deterministic");
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.train_loss, b.train_loss);
    assert_eq!(a.local_step_trace.len(), a.sync_rounds);
    assert_eq!(a.local_step_trace.iter().sum::<usize>(), 24);
    assert!(a.local_step_trace.iter().all(|&h| (1..=8).contains(&h)));
}

#[test]
fn local_step_checkpoint_resume_is_bit_exact() {
    // Round-aligned periodic checkpoints: a checkpoint_every that lands
    // mid-round fires at the covering round's boundary; resuming from
    // the saved file must continue bitwise onto the uninterrupted run —
    // for fixed H and, via the persisted controller carry, for auto.
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("adacons_local_step_ckpt");
    for (spec, tag) in [("4", "fixed"), ("auto:2-8", "auto")] {
        let path = dir.join(format!("{tag}.ckpt"));
        let mk = |steps: usize, checkpointing: bool| {
            let mut cfg = sgd_cfg("adacons-norm", steps);
            cfg.local_steps = LocalStepSpec::parse(spec).unwrap();
            if checkpointing {
                // One qualifying local step (s=10): fires at the round
                // boundary covering it, which with H <= 8 lands at 18
                // at the latest — strictly inside the 20-step run.
                cfg.checkpoint_every = 11;
                cfg.checkpoint_path = Some(path.to_string_lossy().into_owned());
            }
            cfg
        };
        let full = Trainer::new(rt.clone(), mk(20, true)).unwrap().run().unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert!(ck.step > 0 && ck.step < 20, "{tag}: checkpoint step {}", ck.step);
        if tag == "fixed" {
            // H=4 rounds: step 10 lives in [8,12) -> saved at the
            // round boundary 12, H-grid aligned. No controller carry.
            assert_eq!(ck.step, 12);
            assert!(ck.local_h.is_none());
        } else {
            assert!(ck.local_h.is_some(), "auto run must persist its H carry");
        }
        let mut resumed = Trainer::new(rt.clone(), mk(20 - ck.step as usize, false)).unwrap();
        resumed.restore(&ck).unwrap();
        let tail = resumed.run().unwrap();
        assert_eq!(
            tail.final_params, full.final_params,
            "{tag}: resume diverged from the uninterrupted run"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
