//! Integration smoke of the experiment harness at a tiny budget.
//!
//! The figures that only need the interpretable artifacts (fig2 linreg,
//! the bucket ablation on the MLP) run in **every** build via the native
//! interpreter; figures needing the full artifact set (det/dlrm/tfm)
//! still require a `--features pjrt` build with artifacts and skip
//! otherwise.

use std::sync::Arc;

use adacons::runtime::{Backend, Manifest, Runtime};
use adacons::util::argparse::Args;

/// Full artifact set on PJRT (toolchain images only).
fn full_runtime() -> Option<Arc<Runtime>> {
    if !Runtime::HAS_PJRT {
        return None;
    }
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Arc::new(Runtime::create(dir).unwrap()))
    } else {
        None
    }
}

/// Interpretable artifacts on the native backend (always available).
fn interp_runtime() -> Arc<Runtime> {
    Arc::new(Runtime::open_default_with(Backend::Interp).expect("interp backend"))
}

fn tiny_args(out: &std::path::Path, extra: &str) -> Args {
    let s = format!(
        "--out-dir {} --steps-scale 0.04 --workers 2 --local-batches 16 {extra}",
        out.display()
    );
    Args::parse(s.split_whitespace().map(String::from), &[])
}

#[test]
fn fig2_writes_csvs() {
    let rt = interp_runtime();
    let dir = std::env::temp_dir().join("adacons_exp_smoke_fig2");
    adacons::exp::run_figure(rt, "fig2", &tiny_args(&dir, "")).unwrap();
    assert!(dir.join("fig2_curves.csv").exists());
    assert!(dir.join("fig2_summary.csv").exists());
    let text = std::fs::read_to_string(dir.join("fig2_summary.csv")).unwrap();
    assert!(text.lines().count() > 2, "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bucket_ablation_writes_csv() {
    let rt = interp_runtime();
    let dir = std::env::temp_dir().join("adacons_exp_smoke_buckets");
    adacons::exp::run_table(rt, "buckets", &tiny_args(&dir, "")).unwrap();
    let text = std::fs::read_to_string(dir.join("ablation_bucket.csv")).unwrap();
    assert_eq!(text.lines().count(), 5); // header + 4 granularities
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_ids_error() {
    let rt = interp_runtime();
    let args = Args::parse(std::iter::empty(), &[]);
    assert!(adacons::exp::run_figure(rt.clone(), "fig99", &args).is_err());
    assert!(adacons::exp::run_table(rt, "table9", &args).is_err());
}

#[test]
fn fig5_and_fig7_write_csvs() {
    let Some(rt) = full_runtime() else { return };
    let dir = std::env::temp_dir().join("adacons_exp_smoke_fig57");
    adacons::exp::run_figure(rt.clone(), "fig5", &tiny_args(&dir, "")).unwrap();
    assert!(dir.join("fig5_auc.csv").exists());
    adacons::exp::run_figure(rt, "fig7", &tiny_args(&dir, "")).unwrap();
    let text = std::fs::read_to_string(dir.join("fig7_coeff_stages.csv")).unwrap();
    // header + at least one logged step with 7 columns
    let mut lines = text.lines();
    assert_eq!(lines.next().unwrap().split(',').count(), 7);
    assert!(lines.next().is_some());
    std::fs::remove_dir_all(&dir).ok();
}
