//! Parallel == serial equivalence for the sharded aggregation engine.
//!
//! The shard plan and the fixed-order tree reduction depend only on the
//! column range and `min_shard_elems` — never on the thread count — so
//! every kernel must produce **bitwise-identical** results at 1, 2, and
//! `nproc` threads, including ragged shard tails (d not a multiple of
//! CHUNK) and the bucketed `consensus_stats_range` path. `mean_into` /
//! `weighted_sum_range_into` outputs are per-column independent, so they
//! must additionally be bitwise-stable across *different shard plans*.

use adacons::aggregation::{self, Aggregator, CommScope};
use adacons::collective::{CostModel, HierCostModel, NodeMap, SimClock, Topology};
use adacons::comm::StepExchange;
use adacons::compress::{CompressScope, CompressionSpec, CompressorKind, RankCodec};
use adacons::coordinator::pipeline::PipelinedExecutor;
use adacons::parallel::{ParallelCtx, ParallelPolicy};
use adacons::tensor::{grad_set::CHUNK, Buckets, GradSet};
use adacons::util::error::Result;
use adacons::util::proptest::run_cases;

fn nproc() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn ctx(threads: usize, min_shard_elems: usize) -> ParallelCtx {
    ParallelCtx::new(ParallelPolicy {
        threads,
        min_shard_elems,
    })
}

/// Thread counts every property is checked at.
fn thread_grid() -> Vec<usize> {
    let mut t = vec![1, 2, nproc()];
    t.sort_unstable();
    t.dedup();
    t
}

/// Dimensions that exercise: d < CHUNK, d == CHUNK, ragged tails, many
/// shards.
const DIMS: &[usize] = &[17, 1000, 1024, 3 * 1024 + 17, 50_000];

fn random_set(n: usize, d: usize, seed: u64) -> GradSet {
    let mut rng = adacons::util::prng::Rng::new(seed);
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
        .collect();
    GradSet::from_rows(&rows)
}

#[test]
fn consensus_stats_bitwise_equal_at_every_thread_count() {
    for (k, &d) in DIMS.iter().enumerate() {
        let gs = random_set(5, d, 100 + k as u64);
        let base = gs.consensus_stats_ctx(&ctx(1, CHUNK));
        for t in thread_grid() {
            let st = gs.consensus_stats_ctx(&ctx(t, CHUNK));
            assert_eq!(base.dots, st.dots, "dots differ at d={d}, t={t}");
            assert_eq!(base.sqn, st.sqn, "sqn differ at d={d}, t={t}");
        }
    }
}

#[test]
fn default_policy_stats_match_serial_wrapper_bitwise() {
    // The trainer's default context (auto threads, default min shard) must
    // reproduce the library serial wrappers exactly.
    let gs = random_set(8, 200_000, 7);
    let serial = gs.consensus_stats();
    let auto = gs.consensus_stats_ctx(&ParallelCtx::new(ParallelPolicy::default()));
    assert_eq!(serial.dots, auto.dots);
    assert_eq!(serial.sqn, auto.sqn);
}

#[test]
fn prop_range_stats_bitwise_equal_across_threads() {
    run_cases(40, 0xE1, |g| {
        let n = g.usize_in(2, 9);
        let d = g.usize_in(8, 20_000);
        let gs = random_set(n, d, g.case_seed);
        // Unaligned bucket bounds (the layer-wise path).
        let lo = g.usize_in(0, d - 1);
        let hi = g.usize_in(lo + 1, d);
        let min_shard = [CHUNK, 2 * CHUNK, 3000][g.usize_in(0, 2)];
        let base = gs.consensus_stats_range_ctx(lo, hi, &ctx(1, min_shard));
        for t in thread_grid() {
            let st = gs.consensus_stats_range_ctx(lo, hi, &ctx(t, min_shard));
            assert_eq!(base.dots, st.dots, "lo={lo} hi={hi} t={t}");
            assert_eq!(base.sqn, st.sqn, "lo={lo} hi={hi} t={t}");
        }
    });
}

#[test]
fn prop_mean_and_weighted_sum_bitwise_equal_across_threads_and_plans() {
    run_cases(40, 0xE2, |g| {
        let n = g.usize_in(2, 8);
        let d = g.usize_in(4, 20_000);
        let gs = random_set(n, d, g.case_seed);
        let gamma: Vec<f32> = (0..n).map(|_| g.f64_in(-0.5, 1.5) as f32).collect();
        let lo = g.usize_in(0, d - 1);
        let hi = g.usize_in(lo + 1, d);
        let mut base_mean = vec![0.0f32; d];
        gs.mean_into(&mut base_mean);
        let mut base_ws = vec![0.0f32; hi - lo];
        gs.weighted_sum_range_into(&gamma, lo, hi, &mut base_ws);
        // Column outputs are independent: any thread count AND any shard
        // plan must reproduce the serial wrapper bit-for-bit.
        for t in thread_grid() {
            for min_shard in [CHUNK, 4096] {
                let c = ctx(t, min_shard);
                let mut m = vec![0.0f32; d];
                gs.mean_into_ctx(&mut m, &c);
                assert_eq!(base_mean, m, "mean t={t} min_shard={min_shard}");
                let mut w = vec![0.0f32; hi - lo];
                gs.weighted_sum_range_into_ctx(&gamma, lo, hi, &mut w, &c);
                assert_eq!(base_ws, w, "wsum t={t} min_shard={min_shard}");
            }
        }
    });
}

#[test]
fn all_aggregators_bitwise_equal_across_thread_counts() {
    for &d in &[3 * 1024 + 17, 10_000] {
        let n = 6;
        let gs = random_set(n, d, 0xAB);
        let buckets = Buckets::single(d);
        for name in aggregation::ALL_NAMES {
            let mut base_out = vec![0.0f32; d];
            let mut base_agg = aggregation::by_name(name, n).unwrap();
            let base_info = base_agg.aggregate_ctx(&gs, &buckets, &mut base_out, &ctx(1, CHUNK));
            for t in thread_grid() {
                let mut out = vec![0.0f32; d];
                let mut agg = aggregation::by_name(name, n).unwrap();
                let info = agg.aggregate_ctx(&gs, &buckets, &mut out, &ctx(t, CHUNK));
                assert_eq!(base_out, out, "{name} output differs at t={t}, d={d}");
                assert_eq!(base_info.gammas, info.gammas, "{name} gammas at t={t}");
                assert_eq!(
                    info.par.map(|p| (p.shards, p.shard_elems)),
                    base_info.par.map(|p| (p.shards, p.shard_elems)),
                    "{name} shard plan must not depend on threads"
                );
            }
        }
    }
}

#[test]
fn gram_bitwise_equal_across_thread_counts() {
    for (k, &d) in [500usize, 3 * 1024 + 17, 50_000].iter().enumerate() {
        let gs = random_set(6, d, 0x6A + k as u64);
        let base = gs.gram_ctx(&ctx(1, CHUNK));
        for t in thread_grid() {
            assert_eq!(base, gs.gram_ctx(&ctx(t, CHUNK)), "gram differs at d={d} t={t}");
        }
        // Serial wrapper == auto-threaded context at the default policy.
        let auto = gs.gram_ctx(&ParallelCtx::new(ParallelPolicy::default()));
        assert_eq!(gs.gram(), auto, "gram wrapper differs at d={d}");
    }
}

/// Drive one pipelined step over fixed synthetic rows; returns the
/// aggregated output and the step's simulated clock + comm accounting.
fn pipelined_step(
    name: &str,
    rows: &[Vec<f32>],
    buckets: &Buckets,
    threads: usize,
    min_shard: usize,
    overlap: bool,
    compute_s: &[f64],
) -> (Vec<f32>, adacons::coordinator::pipeline::StepOutcome, SimClock) {
    let n = rows.len();
    let d = buckets.total();
    let ctx = ctx(threads, min_shard);
    let mut agg = aggregation::by_name(name, n).unwrap();
    let mut exec = PipelinedExecutor::new(n, buckets.clone(), overlap);
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    let mut produce = |rank: usize,
                       deliver: &mut dyn FnMut(usize, &[f32])|
     -> Result<(f64, f64)> {
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            deliver(b, &rows[rank][lo..hi]);
        }
        Ok((0.0, compute_s[rank]))
    };
    let outcome = exec
        .run_step(
            &mut produce,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap();
    (out, outcome, clock)
}

#[test]
fn overlap_on_off_and_serial_bitwise_equal_all_aggregators() {
    // Acceptance gate: overlap on == overlap off == the serial
    // aggregate_ctx path, for every aggregator, across thread counts and
    // a ragged bucket tail.
    let (n, d) = (5, 4 * CHUNK + 311);
    let gs = random_set(n, d, 0xF00D);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK + 700); // CHUNK-unaligned + ragged tail
    let compute = vec![0.02; n];
    for name in aggregation::ALL_NAMES {
        let mut serial_out = vec![0.0f32; d];
        aggregation::by_name(name, n)
            .unwrap()
            .aggregate_ctx(&gs, &buckets, &mut serial_out, &ctx(1, CHUNK));
        for t in thread_grid() {
            let (on, _, _) = pipelined_step(name, &rows, &buckets, t, CHUNK, true, &compute);
            let (off, _, _) = pipelined_step(name, &rows, &buckets, t, CHUNK, false, &compute);
            assert_eq!(on, off, "{name}: overlap on != off at t={t}");
            assert_eq!(on, serial_out, "{name}: overlap on != serial at t={t}");
        }
    }
}

#[test]
fn prop_overlap_equivalence_ragged_buckets() {
    run_cases(25, 0xE3, |g| {
        let n = g.usize_in(2, 7);
        let d = g.usize_in(8, 15_000);
        let gs = random_set(n, d, g.case_seed);
        let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
        let cap = g.usize_in(1, d); // arbitrary ragged bucketization
        let buckets = Buckets::fixed(d, cap);
        let compute: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 0.1)).collect();
        let names = ["adacons", "mean", "grawa", "adasum", "median"];
        let name = names[g.usize_in(0, names.len() - 1)];
        let min_shard = [CHUNK, 3000][g.usize_in(0, 1)];
        let mut serial_out = vec![0.0f32; d];
        aggregation::by_name(name, n)
            .unwrap()
            .aggregate_ctx(&gs, &buckets, &mut serial_out, &ctx(1, min_shard));
        for t in thread_grid() {
            let (on, _, _) =
                pipelined_step(name, &rows, &buckets, t, min_shard, true, &compute);
            let (off, _, _) =
                pipelined_step(name, &rows, &buckets, t, min_shard, false, &compute);
            assert_eq!(on, off, "{name} d={d} cap={cap} t={t}");
            assert_eq!(on, serial_out, "{name} d={d} cap={cap} t={t}");
        }
    });
}

#[test]
fn straggler_timeline_matches_barrier_semantics_when_overlap_off() {
    // With overlap off, the executor must reproduce the barrier-only
    // SimClock accounting exactly, stragglers included: every rank
    // advances by its own compute, then each comm op is a collective.
    let (n, d) = (3, 2 * CHUNK);
    let gs = random_set(n, d, 0xBEEF);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK);
    let compute = vec![0.1, 0.5, 0.2]; // rank 1 straggles
    let (_, outcome, clock) =
        pipelined_step("adacons", &rows, &buckets, 2, CHUNK, false, &compute);
    // Hand-driven barrier accounting over the same reported ops.
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    let mut manual = SimClock::new(n);
    for (r, &c) in compute.iter().enumerate() {
        manual.advance(r, c);
    }
    for op in &outcome.info.comm {
        manual.collective(cost.time_s(op.kind, op.bytes));
    }
    assert!((clock.now() - manual.now()).abs() < 1e-15, "{} vs {}", clock.now(), manual.now());
    // Off = everything exposed.
    assert!((outcome.exposed_comm_s - outcome.serial_comm_s).abs() < 1e-15);
    // And the straggler paces the step: completion > its compute time.
    assert!(clock.now() > 0.5);
}

#[test]
fn overlap_on_reports_strictly_less_exposed_comm_multi_bucket() {
    let (n, d) = (4, 8 * CHUNK);
    let gs = random_set(n, d, 0xACE);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK);
    let compute = vec![0.05; n];
    for name in aggregation::ALL_NAMES {
        let (_, on, clock_on) =
            pipelined_step(name, &rows, &buckets, 2, CHUNK, true, &compute);
        let (_, off, clock_off) =
            pipelined_step(name, &rows, &buckets, 2, CHUNK, false, &compute);
        // Same ops, same serial accounting...
        assert!(
            (on.serial_comm_s - off.serial_comm_s).abs() < 1e-12,
            "{name}: serial accounting drifted"
        );
        // ...but pipelining hides bucketed transfers behind compute for
        // every scheme that has any (adasum is fully exposed by design).
        if name != &"adasum" {
            assert!(
                on.exposed_comm_s < off.exposed_comm_s,
                "{name}: {} !< {}",
                on.exposed_comm_s,
                off.exposed_comm_s
            );
            assert!(clock_on.now() < clock_off.now(), "{name}: sim time not reduced");
        } else {
            assert!(on.exposed_comm_s <= off.exposed_comm_s + 1e-15, "{name}");
        }
    }
}

/// Drive one pipelined step fed by **real rank threads** over the step
/// exchange: each rank submits its row's buckets from its own OS thread
/// (submission order rotated per rank and round so the leader's
/// arrival-order ingest sees genuinely different interleavings), then a
/// `Done` report; the leader runs `run_step_exchange`.
fn exchange_step(
    name: &str,
    rows: &[Vec<f32>],
    buckets: &Buckets,
    threads: usize,
    min_shard: usize,
    overlap: bool,
    compute_s: &[f64],
    round: usize,
) -> Vec<f32> {
    let n = rows.len();
    let d = buckets.total();
    let (exchange, ports) = StepExchange::new(n);
    let mut handles = Vec::new();
    for port in ports {
        let rank = port.rank();
        let row = rows[rank].clone();
        let bk = buckets.clone();
        let cs = compute_s[rank];
        handles.push(std::thread::spawn(move || {
            let nb = bk.len();
            for i in 0..nb {
                let b = (i + rank + round) % nb;
                let (lo, hi) = bk.range(b);
                port.submit_bucket(b, row[lo..hi].to_vec());
            }
            port.done(0.0, cs);
            port.complete();
        }));
    }
    let ctx = ctx(threads, min_shard);
    let mut agg = aggregation::by_name(name, n).unwrap();
    let mut exec = PipelinedExecutor::new(n, buckets.clone(), overlap);
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    exec.run_step_exchange(
        &exchange,
        agg.as_mut(),
        &mut grads,
        &mut out,
        &ctx,
        &mut clock,
        &cost,
    )
    .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    out
}

/// The five aggregator families the acceptance criterion names.
const FIVE: &[&str] = &["adacons", "mean", "grawa", "adasum", "median"];

#[test]
fn threaded_exchange_bitwise_equals_roundrobin_all_aggregators() {
    // Acceptance gate for the threaded rank runtime: N rank threads
    // streaming buckets in arbitrary arrival order must produce the
    // exact bits of the round-robin producer path, for all five
    // aggregators, under ragged buckets and 1/2/nproc pool threads.
    // Repeat-run (20 rounds, rotated submission orders + OS scheduling
    // noise) to shake out interleaving-dependent bugs.
    let (n, d) = (5, 2 * CHUNK + 311);
    let gs = random_set(n, d, 0x7E4D);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 2 + 177); // ragged, CHUNK-unaligned
    let compute = vec![0.01; n];
    for name in FIVE {
        for t in thread_grid() {
            let (base, _, _) = pipelined_step(name, &rows, &buckets, t, CHUNK, true, &compute);
            for round in 0..20 {
                let got =
                    exchange_step(name, &rows, &buckets, t, CHUNK, true, &compute, round);
                assert_eq!(base, got, "{name}: t={t} round={round}");
            }
        }
    }
}

#[test]
fn threaded_exchange_matches_with_overlap_off_too() {
    // The exchange-fed path must also be exact in the unpipelined mode
    // (arrival order ≠ ingest-task order is not the only hazard; plain
    // assembly indexing must hold as well).
    let (n, d) = (4, CHUNK + 123);
    let gs = random_set(n, d, 0x0FF);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, 200);
    let compute = vec![0.02; n];
    for name in FIVE {
        let (base, _, _) = pipelined_step(name, &rows, &buckets, 2, CHUNK, false, &compute);
        for round in 0..5 {
            let got = exchange_step(name, &rows, &buckets, 2, CHUNK, false, &compute, round);
            assert_eq!(base, got, "{name}: round={round}");
        }
    }
}

#[test]
fn threaded_rank_panic_fails_step_with_rank_id_instead_of_hanging() {
    // Regression: a rank thread dying mid-step must fail the step with a
    // diagnostic naming the rank — never deadlock the leader's ingest.
    let (n, d) = (3, 2 * CHUNK);
    let gs = random_set(n, d, 0xDEAD);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK);
    let (exchange, ports) = StepExchange::new(n);
    let mut handles = Vec::new();
    for port in ports {
        let rank = port.rank();
        let row = rows[rank].clone();
        let bk = buckets.clone();
        handles.push(std::thread::spawn(move || {
            if rank == 2 {
                let (lo, hi) = bk.range(1);
                port.submit_bucket(1, row[lo..hi].to_vec());
                panic!("injected rank death");
            }
            for (b, (lo, hi)) in bk.iter().enumerate() {
                port.submit_bucket(b, row[lo..hi].to_vec());
            }
            port.done(0.0, 0.01);
            port.complete();
        }));
    }
    let ctx = ctx(2, CHUNK);
    let mut agg = aggregation::by_name("adacons", n).unwrap();
    let mut exec = PipelinedExecutor::new(n, buckets.clone(), true);
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    let err = exec
        .run_step_exchange(
            &exchange,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap_err();
    assert!(err.to_string().contains("rank 2"), "{err}");
    for (rank, h) in handles.into_iter().enumerate() {
        assert_eq!(h.join().is_err(), rank == 2, "rank {rank}");
    }
}

/// Drive one pipelined step with a **two-level hierarchical** aggregator
/// through the grouped executor (per-node-group ingest tasks), fed by the
/// round-robin producer. `hier_cost` switches on the two-level timeline.
fn hier_pipelined_step(
    name: &str,
    rows: &[Vec<f32>],
    buckets: &Buckets,
    threads: usize,
    min_shard: usize,
    overlap: bool,
    compute_s: &[f64],
    map: &NodeMap,
    hier_cost: Option<HierCostModel>,
    topo: &Topology,
) -> (Vec<f32>, adacons::coordinator::pipeline::StepOutcome, SimClock) {
    let n = rows.len();
    let d = buckets.total();
    let ctx = ctx(threads, min_shard);
    let mut agg = aggregation::hierarchical(name, map.clone(), n).unwrap();
    let mut exec = PipelinedExecutor::with_topology(
        n,
        buckets.clone(),
        overlap,
        Some(map.clone()),
        hier_cost,
    );
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(topo);
    let mut produce = |rank: usize,
                       deliver: &mut dyn FnMut(usize, &[f32])|
     -> Result<(f64, f64)> {
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            deliver(b, &rows[rank][lo..hi]);
        }
        Ok((0.0, compute_s[rank]))
    };
    let outcome = exec
        .run_step(
            &mut produce,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap();
    (out, outcome, clock)
}

#[test]
fn hier_two_level_bitwise_equal_across_threads_and_overlap() {
    // Acceptance gate for the hierarchy subsystem: the grouped executor
    // (per-node ingest tasks, overlap on or off, any pool thread count)
    // must produce the exact bits of the hierarchical aggregator's
    // inline path — for all five aggregator families, on even and
    // uneven node maps, with a ragged CHUNK-unaligned bucketization.
    let (n, d) = (6usize, 2 * CHUNK + 311);
    let gs = random_set(n, d, 0x41E7);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 2 + 133);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    for map in [NodeMap::even(2, 3), NodeMap::from_sizes(&[3, 2, 1])] {
        for name in FIVE {
            let mut oracle = vec![0.0f32; d];
            aggregation::hierarchical(name, map.clone(), n)
                .unwrap()
                .aggregate_ctx(&gs, &buckets, &mut oracle, &ctx(1, CHUNK));
            for t in thread_grid() {
                for overlap in [true, false] {
                    let (out, _, _) = hier_pipelined_step(
                        name, &rows, &buckets, t, CHUNK, overlap, &compute, &map, None,
                        &topo,
                    );
                    assert_eq!(
                        out, oracle,
                        "{name}: map {map:?} t={t} overlap={overlap}"
                    );
                }
            }
        }
    }
}

#[test]
fn hier_degenerate_maps_bitwise_identical_to_flat_through_executor() {
    // hier:1xN and hier:Nx1 must reproduce the flat path bit-for-bit,
    // through the full executor (both delegate: the wrapper to its base,
    // the executor to the flat ingest path).
    let (n, d) = (4usize, 2 * CHUNK + 55);
    let gs = random_set(n, d, 0xD2);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, 500);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    for name in FIVE {
        let (flat, _, _) = pipelined_step(name, &rows, &buckets, 2, CHUNK, true, &compute);
        for map in [NodeMap::even(1, n), NodeMap::even(n, 1)] {
            let (hier, _, _) = hier_pipelined_step(
                name, &rows, &buckets, 2, CHUNK, true, &compute, &map, None, &topo,
            );
            assert_eq!(flat, hier, "{name}: degenerate {map:?} != flat");
        }
    }
}

/// Exchange-fed hierarchical step: rank threads on a **grouped** exchange
/// submit their buckets in rotated order; the leader runs the grouped
/// executor. Returns the aggregated output.
fn hier_exchange_step(
    name: &str,
    rows: &[Vec<f32>],
    buckets: &Buckets,
    threads: usize,
    overlap: bool,
    compute_s: &[f64],
    map: &NodeMap,
    round: usize,
) -> Vec<f32> {
    let n = rows.len();
    let d = buckets.total();
    let (exchange, ports) = StepExchange::new_grouped(map);
    let mut handles = Vec::new();
    for port in ports {
        let rank = port.rank();
        let row = rows[rank].clone();
        let bk = buckets.clone();
        let cs = compute_s[rank];
        handles.push(std::thread::spawn(move || {
            let nb = bk.len();
            for i in 0..nb {
                let b = (i + rank + round) % nb;
                let (lo, hi) = bk.range(b);
                port.submit_bucket(b, row[lo..hi].to_vec());
            }
            port.done(0.0, cs);
            port.complete();
        }));
    }
    let ctx = ctx(threads, CHUNK);
    let mut agg = aggregation::hierarchical(name, map.clone(), n).unwrap();
    let mut exec =
        PipelinedExecutor::with_topology(n, buckets.clone(), overlap, Some(map.clone()), None);
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    exec.run_step_exchange(
        &exchange,
        agg.as_mut(),
        &mut grads,
        &mut out,
        &ctx,
        &mut clock,
        &cost,
    )
    .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    out
}

#[test]
fn threaded_exchange_hier_bitwise_equals_roundrobin() {
    // The threaded acceptance gate extended to the hierarchy: N rank
    // threads on a grouped exchange, arbitrary arrival interleavings,
    // must produce the producer path's exact bits for all five base
    // aggregators on an uneven map (repeat-run with rotated submission
    // orders to vary which node completes each bucket first).
    let (n, d) = (6usize, CHUNK + 211);
    let gs = random_set(n, d, 0x7E4E);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 4 + 57);
    let compute = vec![0.01; n];
    let map = NodeMap::from_sizes(&[3, 2, 1]);
    let topo = Topology::ring_gbps(n, 100.0);
    for name in FIVE {
        for t in thread_grid() {
            let (base, _, _) = hier_pipelined_step(
                name, &rows, &buckets, t, CHUNK, true, &compute, &map, None, &topo,
            );
            for round in 0..12 {
                let got =
                    hier_exchange_step(name, &rows, &buckets, t, true, &compute, &map, round);
                assert_eq!(base, got, "{name}: t={t} round={round}");
            }
        }
    }
}

#[test]
fn hier_timeline_exposes_less_inter_comm_than_flat_single_nic() {
    // Acceptance: on the paper's 8x4 testbed, the hierarchical timeline
    // (per-node NVLink reduces + leader-level consensus over 8 ranks)
    // must report strictly less exposed inter-node communication than the
    // flat single-NIC model aggregating 32 ranks over the bottleneck
    // fabric.
    let topo = Topology::paper_testbed();
    let n = topo.n_ranks();
    let d = 8 * CHUNK;
    let gs = random_set(n, d, 0xFA81);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK);
    let compute = vec![5e-4; n]; // small compute: comm is what's measured
    // Flat single-NIC baseline: plain adacons over all 32 ranks, every
    // transfer on the bottleneck link.
    let flat = {
        let ctx = ctx(2, CHUNK);
        let mut agg = aggregation::by_name("adacons", n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), true);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&topo);
        let mut produce = |rank: usize,
                           deliver: &mut dyn FnMut(usize, &[f32])|
         -> Result<(f64, f64)> {
            for (b, (lo, hi)) in buckets.iter().enumerate() {
                deliver(b, &rows[rank][lo..hi]);
            }
            Ok((0.0, compute[rank]))
        };
        exec.run_step(
            &mut produce,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap()
    };
    assert_eq!(flat.exposed_intra_comm_s, 0.0);
    assert!(flat.exposed_comm_s > 0.0);
    assert!((flat.exposed_inter_comm_s - flat.exposed_comm_s).abs() < 1e-15);
    // Hierarchical: two-level aggregation + two-level timeline.
    let hier = HierCostModel::from_topology(&topo).unwrap();
    let map = hier.map.clone();
    let (_, hier_on, _) = hier_pipelined_step(
        "adacons",
        &rows,
        &buckets,
        2,
        CHUNK,
        true,
        &compute,
        &map,
        Some(hier),
        &topo,
    );
    assert!(
        hier_on.exposed_inter_comm_s < flat.exposed_comm_s,
        "hier inter {} !< flat {}",
        hier_on.exposed_inter_comm_s,
        flat.exposed_comm_s
    );
    // The serial (fully exposed) accounting is overlap-invariant on the
    // hierarchical path too.
    let hier2 = HierCostModel::from_topology(&topo).unwrap();
    let (_, hier_off, _) = hier_pipelined_step(
        "adacons",
        &rows,
        &buckets,
        2,
        CHUNK,
        false,
        &compute,
        &map,
        Some(hier2),
        &topo,
    );
    assert!(
        (hier_on.serial_comm_s - hier_off.serial_comm_s).abs() < 1e-12,
        "{} vs {}",
        hier_on.serial_comm_s,
        hier_off.serial_comm_s
    );
    assert!(hier_on.exposed_comm_s < hier_off.exposed_comm_s);
}

/// Trainer-shaped compressed step. Per-rank codecs encode at the rank
/// source and the leader edge decodes (the wire round-trip) for the
/// per-rank kinds when flat or hier with scope `all`; the executor owns
/// the leader-side sketch for flat lowrank; the hierarchical aggregator
/// owns the leader-set codec whenever a node map is present. Mirrors the
/// placement logic in `Trainer::new` exactly.
#[allow(clippy::too_many_arguments)]
fn compressed_step(
    name: &str,
    rows: &[Vec<f32>],
    buckets: &Buckets,
    threads: usize,
    overlap: bool,
    compute_s: &[f64],
    spec: CompressionSpec,
    seed: u64,
    map: Option<&NodeMap>,
    hier_cost: Option<HierCostModel>,
    topo: &Topology,
) -> (Vec<f32>, adacons::coordinator::pipeline::StepOutcome) {
    let n = rows.len();
    let d = buckets.total();
    let ctx = ctx(threads, CHUNK);
    let mut agg = match map {
        Some(m) => {
            let mut a = aggregation::hierarchical(name, m.clone(), n).unwrap();
            if !spec.kind.is_none() {
                a.set_compression(spec.kind, seed, buckets.len());
            }
            a
        }
        None => aggregation::by_name(name, n).unwrap(),
    };
    let mut exec = match map {
        Some(m) => PipelinedExecutor::with_topology(
            n,
            buckets.clone(),
            overlap,
            Some(m.clone()),
            hier_cost,
        ),
        None => PipelinedExecutor::new(n, buckets.clone(), overlap),
    };
    exec.set_compression(spec, seed);
    let per_rank =
        spec.kind.is_per_rank() && (map.is_none() || spec.scope == CompressScope::All);
    let mut codecs: Vec<RankCodec> = if per_rank {
        (0..n)
            .map(|r| RankCodec::new(spec.kind, seed, r, buckets.len()))
            .collect()
    } else {
        Vec::new()
    };
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(topo);
    let mut produce = |rank: usize,
                       deliver: &mut dyn FnMut(usize, &[f32])|
     -> Result<(f64, f64)> {
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            if codecs.is_empty() {
                deliver(b, &rows[rank][lo..hi]);
            } else {
                let cols = codecs[rank]
                    .encode_bucket(0, b, &rows[rank][lo..hi])
                    .into_cols();
                deliver(b, &cols);
            }
        }
        Ok((0.0, compute_s[rank]))
    };
    let outcome = exec
        .run_step(
            &mut produce,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap();
    (out, outcome)
}

/// Exchange-fed compressed step (flat, per-rank kinds): each rank thread
/// owns its codec and ships the **encoded wire payload** through
/// `submit_payload`; the leader decodes at the ingest edge. Submission
/// order is rotated per rank and round.
fn compressed_exchange_step(
    rows: &[Vec<f32>],
    buckets: &Buckets,
    threads: usize,
    overlap: bool,
    spec: CompressionSpec,
    seed: u64,
    round: usize,
) -> Vec<f32> {
    let n = rows.len();
    let d = buckets.total();
    let (exchange, ports) = StepExchange::new(n);
    let mut handles = Vec::new();
    for port in ports {
        let rank = port.rank();
        let row = rows[rank].clone();
        let bk = buckets.clone();
        handles.push(std::thread::spawn(move || {
            let mut codec = RankCodec::new(spec.kind, seed, rank, bk.len());
            let nb = bk.len();
            for i in 0..nb {
                let b = (i + rank + round) % nb;
                let (lo, hi) = bk.range(b);
                port.submit_payload(b, codec.encode_bucket(0, b, &row[lo..hi]));
            }
            port.done(0.0, 0.01);
            port.complete();
        }));
    }
    let ctx = ctx(threads, CHUNK);
    let mut agg = aggregation::by_name("adacons", n).unwrap();
    let mut exec = PipelinedExecutor::new(n, buckets.clone(), overlap);
    exec.set_compression(spec, seed);
    let mut grads = GradSet::zeros(n, d);
    let mut out = vec![0.0f32; d];
    let mut clock = SimClock::new(n);
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    exec.run_step_exchange(
        &exchange,
        agg.as_mut(),
        &mut grads,
        &mut out,
        &ctx,
        &mut clock,
        &cost,
    )
    .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    out
}

#[test]
fn compress_none_bitwise_identical_for_five_aggregators_flat_and_hier() {
    // Acceptance gate: `--compress none` must be a bitwise no-op for all
    // five aggregator families, flat and hierarchical, overlap on/off,
    // across pool thread counts — the spec routes through `Payload::Raw`
    // and must never touch the numbers.
    let (n, d) = (6usize, 2 * CHUNK + 311);
    let gs = random_set(n, d, 0xC0DE);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 2 + 177);
    let compute = vec![0.01; n];
    let none = CompressionSpec::default();
    let topo = Topology::ring_gbps(n, 100.0);
    let map = NodeMap::even(2, 3);
    for name in FIVE {
        for t in thread_grid() {
            for overlap in [true, false] {
                let (flat_base, _, _) =
                    pipelined_step(name, &rows, &buckets, t, CHUNK, overlap, &compute);
                let (flat_got, _) = compressed_step(
                    name, &rows, &buckets, t, overlap, &compute, none, 9, None, None, &topo,
                );
                assert_eq!(flat_base, flat_got, "{name}: flat t={t} overlap={overlap}");
                let (hier_base, _, _) = hier_pipelined_step(
                    name, &rows, &buckets, t, CHUNK, overlap, &compute, &map, None, &topo,
                );
                let (hier_got, _) = compressed_step(
                    name, &rows, &buckets, t, overlap, &compute, none, 9, Some(&map), None,
                    &topo,
                );
                assert_eq!(hier_base, hier_got, "{name}: hier t={t} overlap={overlap}");
            }
        }
    }
}

#[test]
fn compress_per_rank_codecs_bitwise_across_threads_overlap_and_aggregators() {
    // For a fixed config the encode→decode round-trip is deterministic
    // (the stochastic rounding is keyed on (step, rank, bucket), never on
    // arrival order), so the compressed step must be bitwise-stable
    // across pool thread counts, overlap modes, and aggregators see the
    // same decoded bits.
    let (n, d) = (5usize, 2 * CHUNK + 311);
    let gs = random_set(n, d, 0x517E);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 2 + 133);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    for kind_s in ["int8", "fp16", "topk:0.25"] {
        let spec = CompressionSpec {
            kind: CompressorKind::parse(kind_s).unwrap(),
            scope: CompressScope::All,
        };
        for name in FIVE {
            let (base, _) = compressed_step(
                name, &rows, &buckets, 1, true, &compute, spec, 17, None, None, &topo,
            );
            for t in thread_grid() {
                for overlap in [true, false] {
                    let (got, _) = compressed_step(
                        name, &rows, &buckets, t, overlap, &compute, spec, 17, None, None,
                        &topo,
                    );
                    assert_eq!(base, got, "{kind_s}/{name}: t={t} overlap={overlap}");
                }
            }
        }
    }
}

#[test]
fn compress_threaded_wire_payloads_bitwise_equal_roundrobin() {
    // Rank threads shipping *encoded* payloads through the exchange (the
    // real wire path: encode at the rank source, decode at the leader
    // edge, arbitrary arrival interleavings) must reproduce the
    // round-robin producer's exact bits.
    let (n, d) = (5usize, CHUNK + 211);
    let gs = random_set(n, d, 0x77E);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 4 + 57);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    for kind_s in ["int8", "fp16", "topk:0.25"] {
        let spec = CompressionSpec {
            kind: CompressorKind::parse(kind_s).unwrap(),
            scope: CompressScope::All,
        };
        let (base, _) = compressed_step(
            "adacons", &rows, &buckets, 2, true, &compute, spec, 23, None, None, &topo,
        );
        for t in thread_grid() {
            for round in 0..8 {
                let got =
                    compressed_exchange_step(&rows, &buckets, t, true, spec, 23, round);
                assert_eq!(base, got, "{kind_s}: t={t} round={round}");
            }
        }
    }
}

#[test]
fn compress_lowrank_leader_sketch_bitwise_across_threads_and_overlap() {
    // Flat lowrank is a leader-side set transform (sequential f64 power
    // iteration per bucket): overlap on == off == any pool thread count,
    // bit for bit.
    let (n, d) = (5usize, 2 * CHUNK + 311);
    let gs = random_set(n, d, 0x10E);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 2 + 177);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    let spec = CompressionSpec {
        kind: CompressorKind::parse("lowrank:2").unwrap(),
        scope: CompressScope::All,
    };
    let (base, _) = compressed_step(
        "adacons", &rows, &buckets, 1, false, &compute, spec, 31, None, None, &topo,
    );
    assert!(base.iter().all(|v| v.is_finite()));
    for t in thread_grid() {
        for overlap in [true, false] {
            let (got, _) = compressed_step(
                "adacons", &rows, &buckets, t, overlap, &compute, spec, 31, None, None, &topo,
            );
            assert_eq!(base, got, "lowrank: t={t} overlap={overlap}");
        }
    }
}

#[test]
fn compress_hier_grouped_executor_equals_inline_oracle() {
    // Hierarchical compression lives inside the aggregator (leader-set
    // codec), so the grouped executor must reproduce the inline
    // `aggregate_ctx` path bit for bit — for every compressor kind, on
    // even and uneven maps, overlap on/off, any pool thread count.
    let (n, d) = (6usize, CHUNK + 211);
    let gs = random_set(n, d, 0xA11);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK / 4 + 57);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    for map in [NodeMap::even(2, 3), NodeMap::from_sizes(&[3, 2, 1])] {
        for kind_s in ["int8", "fp16", "topk:0.25", "lowrank:2"] {
            let kind = CompressorKind::parse(kind_s).unwrap();
            let spec = CompressionSpec {
                kind,
                scope: CompressScope::Inter,
            };
            let mut oracle = vec![0.0f32; d];
            let mut inline = aggregation::hierarchical("adacons", map.clone(), n).unwrap();
            inline.set_compression(kind, 41, buckets.len());
            inline.aggregate_ctx(&gs, &buckets, &mut oracle, &ctx(1, CHUNK));
            for t in thread_grid() {
                for overlap in [true, false] {
                    let (got, _) = compressed_step(
                        "adacons", &rows, &buckets, t, overlap, &compute, spec, 41,
                        Some(&map), None, &topo,
                    );
                    assert_eq!(
                        got, oracle,
                        "{kind_s}: map {map:?} t={t} overlap={overlap}"
                    );
                }
            }
        }
    }
}

#[test]
fn compress_hier_scope_all_composes_rank_codecs_with_leader_codec() {
    // hier + scope `all` applies BOTH the per-rank wire codec and the
    // leader-set codec. Oracle: decode(encode(rows)) through fresh rank
    // codecs, then the inline hierarchical path with the set codec.
    let (n, d) = (6usize, CHUNK + 123);
    let gs = random_set(n, d, 0xA22);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, 300);
    let compute = vec![0.01; n];
    let topo = Topology::ring_gbps(n, 100.0);
    let map = NodeMap::even(2, 3);
    let kind = CompressorKind::parse("int8").unwrap();
    let spec = CompressionSpec {
        kind,
        scope: CompressScope::All,
    };
    // Oracle: materialize the decoded rank rows, then run inline.
    let mut decoded = GradSet::zeros(n, d);
    for rank in 0..n {
        let mut codec = RankCodec::new(kind, 43, rank, buckets.len());
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            let cols = codec.encode_bucket(0, b, &rows[rank][lo..hi]).into_cols();
            decoded.row_mut(rank)[lo..hi].copy_from_slice(&cols);
        }
    }
    let mut oracle = vec![0.0f32; d];
    let mut inline = aggregation::hierarchical("adacons", map.clone(), n).unwrap();
    inline.set_compression(kind, 43, buckets.len());
    inline.aggregate_ctx(&decoded, &buckets, &mut oracle, &ctx(1, CHUNK));
    for t in thread_grid() {
        let (got, _) = compressed_step(
            "adacons", &rows, &buckets, t, true, &compute, spec, 43, Some(&map), None, &topo,
        );
        assert_eq!(got, oracle, "t={t}");
    }
}

#[test]
fn compress_int8_inter_cuts_exposed_inter_comm_on_paper_testbed() {
    // Acceptance gate: `--compress int8 --compress-scope inter` on the
    // paper's 8x4 testbed must report strictly lower exposed inter-node
    // communication than the uncompressed hierarchical run — int8 cuts
    // every bucket's inter-node transfer to (w + 4) bytes from 4w — while
    // the aggregated output stays close to the uncompressed one.
    let topo = Topology::paper_testbed();
    let n = topo.n_ranks();
    let d = 8 * CHUNK;
    let gs = random_set(n, d, 0xFA82);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| gs.row(i).to_vec()).collect();
    let buckets = Buckets::fixed(d, CHUNK);
    let compute = vec![5e-4; n];
    let map = HierCostModel::from_topology(&topo).unwrap().map.clone();
    let run = |spec: CompressionSpec| {
        let hc = HierCostModel::from_topology(&topo).unwrap();
        compressed_step(
            "adacons", &rows, &buckets, 2, false, &compute, spec, 47, Some(&map), Some(hc),
            &topo,
        )
    };
    let (base_out, base) = run(CompressionSpec::default());
    let (int8_out, int8) = run(CompressionSpec {
        kind: CompressorKind::parse("int8").unwrap(),
        scope: CompressScope::Inter,
    });
    assert!(base.exposed_inter_comm_s > 0.0);
    assert!(
        int8.exposed_inter_comm_s < base.exposed_inter_comm_s,
        "int8 inter {} !< uncompressed {}",
        int8.exposed_inter_comm_s,
        base.exposed_inter_comm_s
    );
    // The reported wire bytes shrink too: every rewritten inter op
    // carries (w + 4) bytes instead of 4w.
    let inter_bytes = |ops: &adacons::coordinator::pipeline::StepOutcome| -> usize {
        ops.info
            .comm
            .iter()
            .filter(|op| op.scope == CommScope::Inter && op.bucket.is_some())
            .map(|op| op.bytes)
            .sum()
    };
    assert!(inter_bytes(&int8) < inter_bytes(&base));
    // Intra transfers are untouched at scope `inter`.
    assert!((int8.exposed_intra_comm_s - base.exposed_intra_comm_s).abs() < 1e-15);
    // Sanity on the numbers: finite and near the uncompressed answer
    // (the loss-tolerance argument lives in EXPERIMENTS.md §Compression).
    assert!(int8_out.iter().all(|v| v.is_finite()));
    let max_diff = int8_out
        .iter()
        .zip(base_out.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 0.5, "int8/inter drifted {max_diff} from uncompressed");
}

#[test]
fn compress_error_feedback_reset_matches_fresh_codec_bitwise() {
    // The trainer resets every codec on param re-broadcast (checkpoint
    // restore): after `reset`, a codec must be bitwise the fresh codec.
    for kind_s in ["int8", "fp16", "topk:0.25"] {
        let kind = CompressorKind::parse(kind_s).unwrap();
        let cols: Vec<f32> = (0..300)
            .map(|i| ((i * 37 % 101) as f32 - 50.0) / 17.0)
            .collect();
        let mut used = RankCodec::new(kind, 9, 0, 2);
        for step in 0..3 {
            let _ = used.encode_bucket(step, 0, &cols);
        }
        used.reset();
        let mut fresh = RankCodec::new(kind, 9, 0, 2);
        let a = used.encode_bucket(0, 0, &cols);
        let b = fresh.encode_bucket(0, 0, &cols);
        assert_eq!(a, b, "{kind_s}: reset codec != fresh codec");
    }
}

#[test]
fn bucketed_adacons_bitwise_equal_across_thread_counts() {
    let (n, d) = (5, 7 * 1024 + 311);
    let gs = random_set(n, d, 0xCD);
    // Bucket cap chosen to be CHUNK-unaligned on purpose.
    let buckets = Buckets::fixed(d, 2500);
    let mut base_out = vec![0.0f32; d];
    aggregation::by_name("adacons", n)
        .unwrap()
        .aggregate_ctx(&gs, &buckets, &mut base_out, &ctx(1, CHUNK));
    for t in thread_grid() {
        let mut out = vec![0.0f32; d];
        aggregation::by_name("adacons", n)
            .unwrap()
            .aggregate_ctx(&gs, &buckets, &mut out, &ctx(t, CHUNK));
        assert_eq!(base_out, out, "bucketed adacons differs at t={t}");
    }
}
