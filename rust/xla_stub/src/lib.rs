//! Compile-check stub of the `xla` crate (0.1.6) PJRT surface.
//!
//! This exists so `cargo check --all-targets --features pjrt` works in
//! the default offline checkout — the CI leg that catches feature-gate
//! bitrot in the `#[cfg(feature = "pjrt")]` code paths. Every entry
//! point that would touch XLA returns [`Error::Stub`], so a binary built
//! against the stub fails loudly at `PjRtClient::cpu()` instead of
//! pretending to execute HLO.
//!
//! Toolchain images swap this for the real vendored crate by replacing
//! the `xla = { path = "xla_stub" }` dependency in `rust/Cargo.toml`.

use std::path::Path;

/// The stub's only error: the real crate is not linked.
#[derive(Debug)]
pub enum Error {
    Stub,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(
            "xla stub: built against the compile-check stub of the xla crate; \
             vendor the real crate to execute PJRT artifacts",
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Stub)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub)
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub)
    }
}

/// Host literal (stub).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Stub)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Stub)
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute_b(&self, _buffers: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub)
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Stub)
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_refuses() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
